//! The JSON job API: request routing + the submit-spec ↔ `FarmConfig`
//! mapping, in two versions.
//!
//! `/v2` is the current API (typed [`JobSpec`] submissions, the uniform
//! [`ErrorEnvelope`] error body, and the fine-grained job state machine):
//!
//! | Method | Path                  | Meaning                               |
//! |--------|-----------------------|---------------------------------------|
//! | POST   | `/v2/jobs`            | submit a sweep job (JobSpec JSON body)|
//! | GET    | `/v2/jobs/{id}`       | job status + state machine position   |
//! | GET    | `/v2/jobs/{id}/result`| bit-exact replica report (text/plain) |
//! | GET    | `/v2/healthz`         | liveness + queue/registry counts      |
//! | GET    | `/v2/info`            | engine matrix + analytic constants    |
//! | POST   | `/v2/shutdown`        | graceful stop (checkpoints in-flight) |
//!
//! (The fleet endpoints under `/v2/fleet/*` are served by the
//! coordinator process — see [`super::fleet`].)
//!
//! `/v1` is kept as a thin compatibility shim over the same handlers:
//! identical routes, request bodies, response bodies, and status codes
//! as before the redesign, plus advisory `Deprecation: true` and
//! `Link: </v2>; rel="successor-version"` headers on every response.
//!
//! The submit body carries the same TOML-equivalent sweep configuration
//! the `ising sweep` CLI takes (`size`, `engine`, `betas`/`beta_points`,
//! `replicas`, `seed`, `burn_in`, `samples`, `thin`, `workers`,
//! `shards`), validated by the shared [`JobSpec`] — the *single* parse +
//! validation path for CLI flags, TOML sections, and HTTP JSON. The
//! result body is the exact byte string `ising sweep --report` writes
//! for the same config.

use super::http::{Request, Response};
use super::queue::{Scheduler, Submit};
use super::wire::{ErrorEnvelope, JobSpec};
use crate::config::ServerConfig;
use crate::coordinator::farm::FarmConfig;
#[cfg(test)]
use crate::coordinator::farm::FarmEngine;
use crate::error::{Error, Result};
use crate::obs::{clock, Obs};
use crate::registry::manifest::MANIFEST_MEDIA_TYPE;
use crate::registry::{is_valid_digest, is_valid_tag, Manifest, Store};
use crate::util::json::{obj, Json};
use std::sync::Arc;

/// Shared handler context.
pub struct ApiCtx {
    /// The job scheduler (also carries the stop flag the shutdown
    /// endpoint raises).
    pub scheduler: Arc<Scheduler>,
    /// Serving configuration (echoed by the health endpoint).
    pub server: ServerConfig,
}

/// Parse a submitted job spec (the POST `/v{1,2}/jobs` body) into a
/// farm configuration: the shared [`JobSpec`] decode + resolve (the
/// same path CLI flags and TOML sections take, so the entry points
/// cannot drift), then the service resource caps — one request must
/// not be able to OOM the server (the scheduler re-checks these as a
/// backstop).
pub fn job_config_from_json(doc: &Json) -> Result<FarmConfig> {
    let cfg = JobSpec::from_json(doc)?.resolve()?;
    super::queue::enforce_job_limits(&cfg)?;
    Ok(cfg)
}

/// Route one request. Infallible by construction: every failure becomes
/// a status-coded JSON body — the legacy `{"error": ...}` shape on
/// `/v1`, the [`ErrorEnvelope`] on `/v2`. Every `/v1` response (success
/// or failure) additionally carries the deprecation advisory headers.
pub fn handle(req: &Request, ctx: &ApiCtx) -> Response {
    let segs: Vec<&str> = req.path.split('/').filter(|s| !s.is_empty()).collect();
    let resp = match (req.method.as_str(), segs.as_slice()) {
        // ----- /v1: compatibility shim (bodies + codes unchanged) -----
        ("POST", ["v1", "jobs"]) => submit(req, ctx),
        ("GET", ["v1", "jobs", id]) => job_status(id, ctx),
        ("GET", ["v1", "jobs", id, "result"]) => job_result(id, ctx),
        ("GET", ["v1", "healthz"]) => healthz(ctx),
        ("GET", ["v1", "info"]) => info(ctx),
        ("POST", ["v1", "shutdown"]) => shutdown(ctx),
        // Known paths with the wrong verb get 405, everything else 404.
        (_, ["v1", "jobs"]) | (_, ["v1", "shutdown"]) => error_response(
            405,
            "use POST for this endpoint",
        ),
        (_, ["v1", "jobs", _]) | (_, ["v1", "jobs", _, "result"])
        | (_, ["v1", "healthz"]) | (_, ["v1", "info"]) => {
            error_response(405, "use GET for this endpoint")
        }
        // ----- /v2: current API (ErrorEnvelope + job state machine) -----
        ("POST", ["v2", "jobs"]) => submit_v2(req, ctx),
        ("GET", ["v2", "jobs", id]) => job_status_v2(id, ctx),
        ("GET", ["v2", "jobs", id, "result"]) => job_result_v2(id, ctx),
        ("GET", ["v2", "healthz"]) => healthz(ctx),
        ("GET", ["v2", "info"]) => info(ctx),
        ("GET", ["v2", "metrics"]) => metrics(ctx),
        ("POST", ["v2", "shutdown"]) => shutdown(ctx),
        // ----- /v2/artifacts: the registry push/pull surface -----
        ("GET", ["v2", "artifacts", "tags"]) => {
            artifact_tags(&ctx.scheduler.artifact_store())
        }
        ("GET", ["v2", "artifacts", "manifests", reference @ ..]) => artifact_manifest_get(
            &ctx.scheduler.artifact_store(),
            &ctx.scheduler.obs(),
            &reference.join("/"),
        ),
        ("PUT", ["v2", "artifacts", "manifests", target @ ..]) => artifact_manifest_put(
            &ctx.scheduler.artifact_store(),
            &ctx.scheduler.obs(),
            &target.join("/"),
            &req.body,
        ),
        ("HEAD", ["v2", "artifacts", "blobs", digest]) => {
            artifact_blob_head(&ctx.scheduler.artifact_store(), digest)
        }
        ("GET", ["v2", "artifacts", "blobs", digest]) => {
            artifact_blob_get(&ctx.scheduler.artifact_store(), digest)
        }
        ("PUT", ["v2", "artifacts", "blobs", digest]) => {
            artifact_blob_put(&ctx.scheduler.artifact_store(), digest, &req.body)
        }
        (_, ["v2", "artifacts", "tags"])
        | (_, ["v2", "artifacts", "manifests", ..])
        | (_, ["v2", "artifacts", "blobs", _]) => ErrorEnvelope::new(
            405,
            "usage",
            "artifacts endpoints speak GET/HEAD/PUT",
        )
        .to_response(),
        (_, ["v2", "jobs"]) | (_, ["v2", "shutdown"]) => {
            ErrorEnvelope::new(405, "usage", "use POST for this endpoint").to_response()
        }
        (_, ["v2", "jobs", _]) | (_, ["v2", "jobs", _, "result"])
        | (_, ["v2", "healthz"]) | (_, ["v2", "info"]) | (_, ["v2", "metrics"]) => {
            ErrorEnvelope::new(405, "usage", "use GET for this endpoint").to_response()
        }
        (_, ["v2", ..]) => ErrorEnvelope::new(
            404,
            "not_found",
            format!("no route for '{}'", req.path),
        )
        .to_response(),
        _ => error_response(404, &format!("no route for '{}'", req.path)),
    };
    let code = resp.status.to_string();
    ctx.scheduler.obs().metrics.counter(
        "ising_http_requests_total",
        "HTTP requests handled, by response status code.",
        &[("code", code.as_str())],
        1.0,
    );
    if segs.first() == Some(&"v1") {
        resp.with_header("Deprecation", "true")
            .with_header("Link", "</v2>; rel=\"successor-version\"")
    } else {
        resp
    }
}

/// `GET /v2/metrics` — Prometheus text exposition. Queue and job-state
/// gauges are computed at scrape time from the same registry snapshot
/// `/v2/healthz` reports, so the two endpoints can never disagree.
fn metrics(ctx: &ApiCtx) -> Response {
    let obs = ctx.scheduler.obs();
    let counts = ctx.scheduler.counts();
    obs.metrics.gauge(
        "ising_queue_depth",
        "Jobs waiting in the bounded queue right now.",
        &[],
        counts.queued as f64,
    );
    obs.metrics.gauge(
        "ising_queue_capacity",
        "Configured queue depth cap (submissions past it answer 429).",
        &[],
        ctx.server.queue_depth as f64,
    );
    for (status, n) in [
        ("queued", counts.queued),
        ("running", counts.running),
        ("done", counts.done),
        ("failed", counts.failed),
    ] {
        obs.metrics.gauge(
            "ising_jobs",
            "Jobs in the registry by coarse status.",
            &[("status", status)],
            n as f64,
        );
    }
    let store = ctx.scheduler.artifact_store();
    record_store_gauges(&obs, &store);
    Response::prometheus(obs.metrics.render())
}

/// Scrape-time registry gauges (blob count + store size) — shared by the
/// job server's `/v2/metrics` and the fleet coordinator's.
pub fn record_store_gauges(obs: &Obs, store: &Store) {
    if let Ok(stats) = store.stats() {
        obs.metrics.gauge(
            "registry_store_blobs",
            "Blobs in the artifact registry store right now.",
            &[],
            stats.blobs as f64,
        );
        obs.metrics.gauge(
            "registry_store_size_bytes",
            "Total blob bytes in the artifact registry store right now.",
            &[],
            stats.bytes as f64,
        );
    }
}

// ---------------------------------------------------------------------
// /v2/artifacts handlers — shared verbatim by the job server and (GET
// side) the fleet coordinator, so `ising artifacts push/pull` and worker
// checkpoint pulls speak to one implementation.

/// `GET /v2/artifacts/tags` — every tag with the digest it names.
pub fn artifact_tags(store: &Store) -> Response {
    match store.tags() {
        Ok(tags) => Response::json(
            200,
            &obj(vec![(
                "tags",
                Json::Arr(
                    tags.into_iter()
                        .map(|(name, digest)| {
                            obj(vec![
                                ("name", Json::Str(name)),
                                ("digest", Json::Str(digest)),
                            ])
                        })
                        .collect(),
                ),
            )]),
        ),
        Err(e) => ErrorEnvelope::from_error(&e).to_response(),
    }
}

/// `GET /v2/artifacts/manifests/{ref}` — serve a manifest's canonical
/// bytes by tag or digest (the pull side of a transfer).
pub fn artifact_manifest_get(store: &Store, obs: &Obs, reference: &str) -> Response {
    let started = clock::now();
    let resp = match store.resolve(reference) {
        Err(e) => ErrorEnvelope::new(404, "not_found", e.to_string()).to_response(),
        Ok(digest) if !store.has_blob(&digest) => {
            ErrorEnvelope::new(404, "not_found", format!("no manifest '{reference}'"))
                .to_response()
        }
        Ok(digest) => match store.get_manifest(&digest) {
            Ok(m) => {
                let mut resp = Response::octets(200, m.canonical_bytes());
                resp.content_type = MANIFEST_MEDIA_TYPE;
                resp.with_header("Docker-Content-Digest", digest)
            }
            Err(e) => ErrorEnvelope::from_error(&e).to_response(),
        },
    };
    let code = resp.status.to_string();
    obs.trace.complete(
        "artifact_pull",
        "registry",
        "artifacts",
        started,
        &[("ref", reference), ("code", code.as_str())],
    );
    resp
}

/// `PUT /v2/artifacts/manifests/{tag|digest}` — accept a manifest whose
/// referenced blobs were pushed first; a tag target additionally points
/// the tag at it (the push side of a transfer).
pub fn artifact_manifest_put(store: &Store, obs: &Obs, target: &str, body: &[u8]) -> Response {
    let started = clock::now();
    let resp = artifact_manifest_put_inner(store, target, body);
    let code = resp.status.to_string();
    obs.trace.complete(
        "artifact_push",
        "registry",
        "artifacts",
        started,
        &[("ref", target), ("code", code.as_str())],
    );
    resp
}

fn artifact_manifest_put_inner(store: &Store, target: &str, body: &[u8]) -> Response {
    let doc = match std::str::from_utf8(body).map_err(|_| ()).and_then(|s| {
        Json::parse(s).map_err(|_| ())
    }) {
        Ok(d) => d,
        Err(()) => {
            return ErrorEnvelope::new(400, "usage", "manifest body must be JSON").to_response();
        }
    };
    let manifest = match Manifest::from_json(&doc) {
        Ok(m) => m,
        Err(e) => return ErrorEnvelope::new(400, "usage", e.to_string()).to_response(),
    };
    let digest = manifest.digest();
    if is_valid_digest(target) {
        if target != digest {
            return ErrorEnvelope::new(
                400,
                "usage",
                format!("manifest bytes hash to {digest}, not the requested {target}"),
            )
            .to_response();
        }
    } else if !is_valid_tag(target) {
        return ErrorEnvelope::new(
            400,
            "usage",
            format!("'{target}' is neither a digest nor a valid tag"),
        )
        .to_response();
    }
    match store.put_manifest(&manifest) {
        // Missing layer blobs are the client's sequencing error (push
        // blobs first), not a server fault.
        Err(Error::Artifact(msg)) => ErrorEnvelope::new(400, "usage", msg).to_response(),
        Err(e) => ErrorEnvelope::from_error(&e).to_response(),
        Ok(stored) => {
            if is_valid_tag(target) {
                if let Err(e) = store.tag(target, &stored) {
                    return ErrorEnvelope::from_error(&e).to_response();
                }
            }
            Response::json(200, &obj(vec![("digest", Json::Str(stored))]))
        }
    }
}

/// `HEAD /v2/artifacts/blobs/{digest}` — existence probe (the push side
/// skips blobs the remote already has). Bodyless by protocol; the size
/// rides in a header.
pub fn artifact_blob_head(store: &Store, digest: &str) -> Response {
    if !is_valid_digest(digest) {
        return Response::octets(400, Vec::new());
    }
    match store.blob_size(digest) {
        Some(size) => {
            Response::octets(200, Vec::new()).with_header("X-Blob-Size", size.to_string())
        }
        None => Response::octets(404, Vec::new()),
    }
}

/// `GET /v2/artifacts/blobs/{digest}` — the blob bytes, rehashed against
/// their address before they leave the store.
pub fn artifact_blob_get(store: &Store, digest: &str) -> Response {
    if !is_valid_digest(digest) {
        return ErrorEnvelope::new(400, "usage", "malformed blob digest").to_response();
    }
    if !store.has_blob(digest) {
        return ErrorEnvelope::new(404, "not_found", format!("no blob {digest}")).to_response();
    }
    match store.get_blob(digest) {
        Ok(bytes) => Response::octets(200, bytes),
        Err(e) => ErrorEnvelope::from_error(&e).to_response(),
    }
}

/// `PUT /v2/artifacts/blobs/{digest}` — ingest pushed bytes, refusing
/// (400, nothing stored) when they do not hash to the claimed digest.
pub fn artifact_blob_put(store: &Store, digest: &str, body: &[u8]) -> Response {
    if !is_valid_digest(digest) {
        return ErrorEnvelope::new(400, "usage", "malformed blob digest").to_response();
    }
    match store.put_blob_verified(body, digest) {
        Ok(stored) => Response::json(200, &obj(vec![("digest", Json::Str(stored))])),
        Err(Error::Artifact(msg)) => ErrorEnvelope::new(400, "usage", msg).to_response(),
        Err(e) => ErrorEnvelope::from_error(&e).to_response(),
    }
}

fn error_response(status: u16, msg: &str) -> Response {
    Response::json(status, &obj(vec![("error", Json::Str(msg.to_string()))]))
}

fn shutdown(ctx: &ApiCtx) -> Response {
    ctx.scheduler.request_stop();
    Response::json(200, &obj(vec![("status", Json::Str("stopping".into()))]))
}

fn submit(req: &Request, ctx: &ApiCtx) -> Response {
    let body = match req.body_str() {
        Ok(s) => s,
        Err(e) => return e.into_response(),
    };
    let doc = match Json::parse(body) {
        Ok(d) => d,
        Err(e) => return error_response(400, &format!("invalid JSON body: {e}")),
    };
    let cfg = match job_config_from_json(&doc) {
        Ok(c) => c,
        Err(e) => return error_response(400, &e.to_string()),
    };
    match ctx.scheduler.submit(cfg) {
        Ok(Submit::Accepted { id }) => Response::json(
            202,
            &obj(vec![
                ("id", Json::Str(id)),
                ("status", Json::Str("queued".into())),
            ]),
        ),
        Ok(Submit::Existing { id, status }) => Response::json(
            200,
            &obj(vec![
                ("id", Json::Str(id)),
                ("status", Json::Str(status.name().into())),
            ]),
        ),
        Ok(Submit::Busy) => error_response(
            429,
            &format!(
                "job queue full (depth {}) or shutting down; retry later",
                ctx.server.queue_depth
            ),
        ),
        // The scheduler's own validation backstop is caller error (400);
        // anything else (I/O on the job store) is genuinely ours (500).
        Err(Error::Usage(msg)) => error_response(400, &msg),
        Err(e) => error_response(500, &e.to_string()),
    }
}

fn job_status(id: &str, ctx: &ApiCtx) -> Response {
    if !super::cache::is_valid_id(id) {
        return error_response(400, "job id must be 16 lowercase hex characters");
    }
    match ctx.scheduler.job_summary(id) {
        None => error_response(404, &format!("unknown job '{id}'")),
        Some((status, engine, replicas, samples)) => {
            let mut fields = vec![
                ("id", Json::Str(id.to_string())),
                ("status", Json::Str(status.name().into())),
                ("engine", Json::Str(engine)),
                ("replicas", Json::Num(replicas as f64)),
                ("samples_per_replica", Json::Num(samples as f64)),
            ];
            if let super::queue::JobStatus::Failed(msg) = &status {
                fields.push(("error", Json::Str(msg.clone())));
            }
            Response::json(200, &obj(fields))
        }
    }
}

fn job_result(id: &str, ctx: &ApiCtx) -> Response {
    if !super::cache::is_valid_id(id) {
        return error_response(400, "job id must be 16 lowercase hex characters");
    }
    match ctx.scheduler.status(id) {
        None => error_response(404, &format!("unknown job '{id}'")),
        Some(status) => match ctx.scheduler.result(id) {
            // Byte-identical to `ising sweep --report` for this config.
            Some(report) => Response::text(200, report),
            None => Response::json(
                409,
                &obj(vec![
                    ("id", Json::Str(id.to_string())),
                    ("status", Json::Str(status.name().into())),
                    ("error", Json::Str("job has no result yet".into())),
                ]),
            ),
        },
    }
}

// ---------------------------------------------------------------------
// /v2 handlers: same scheduler, ErrorEnvelope failures, explicit state.

/// The job's fine-grained state name (`/v2` responses). Falls back to
/// "queued" in the unreachable window where a just-accepted job has no
/// registry entry.
fn state_name(id: &str, ctx: &ApiCtx) -> String {
    ctx.scheduler
        .job_state(id)
        .map(|s| s.name().to_string())
        .unwrap_or_else(|| "queued".into())
}

fn submit_v2(req: &Request, ctx: &ApiCtx) -> Response {
    let body = match req.body_str() {
        Ok(s) => s,
        Err(e) => return ErrorEnvelope::new(e.status, "usage", e.msg).to_response(),
    };
    let doc = match Json::parse(body) {
        Ok(d) => d,
        Err(e) => return ErrorEnvelope::from_error(&e).to_response(),
    };
    let cfg = match job_config_from_json(&doc) {
        Ok(c) => c,
        Err(e) => return ErrorEnvelope::from_error(&e).to_response(),
    };
    match ctx.scheduler.submit(cfg) {
        Ok(Submit::Accepted { id }) => {
            let state = state_name(&id, ctx);
            Response::json(
                202,
                &obj(vec![("id", Json::Str(id)), ("state", Json::Str(state))]),
            )
        }
        Ok(Submit::Existing { id, .. }) => {
            let state = state_name(&id, ctx);
            Response::json(
                200,
                &obj(vec![("id", Json::Str(id)), ("state", Json::Str(state))]),
            )
        }
        Ok(Submit::Busy) => ErrorEnvelope::new(
            429,
            "busy",
            format!(
                "job queue full (depth {}) or shutting down; retry later",
                ctx.server.queue_depth
            ),
        )
        .to_response(),
        Err(e) => ErrorEnvelope::from_error(&e).to_response(),
    }
}

fn job_status_v2(id: &str, ctx: &ApiCtx) -> Response {
    if !super::cache::is_valid_id(id) {
        return ErrorEnvelope::new(400, "usage", "job id must be 16 lowercase hex characters")
            .to_response();
    }
    match ctx.scheduler.job_summary(id) {
        None => ErrorEnvelope::new(404, "not_found", format!("unknown job '{id}'")).to_response(),
        Some((status, engine, replicas, samples)) => {
            let mut fields = vec![
                ("id", Json::Str(id.to_string())),
                ("state", Json::Str(state_name(id, ctx))),
                ("status", Json::Str(status.name().into())),
                ("engine", Json::Str(engine)),
                ("replicas", Json::Num(replicas as f64)),
                ("samples_per_replica", Json::Num(samples as f64)),
            ];
            if let super::queue::JobStatus::Failed(msg) = &status {
                fields.push(("error", Json::Str(msg.clone())));
            }
            Response::json(200, &obj(fields))
        }
    }
}

fn job_result_v2(id: &str, ctx: &ApiCtx) -> Response {
    if !super::cache::is_valid_id(id) {
        return ErrorEnvelope::new(400, "usage", "job id must be 16 lowercase hex characters")
            .to_response();
    }
    match ctx.scheduler.status(id) {
        None => ErrorEnvelope::new(404, "not_found", format!("unknown job '{id}'")).to_response(),
        Some(status) => match ctx.scheduler.result(id) {
            // Byte-identical to `ising sweep --report` for this config.
            Some(report) => Response::text(200, report),
            // Not done yet: a retryable conflict — the canonical client
            // poll loop retries exactly the envelopes marked retryable.
            None => ErrorEnvelope::new(
                409,
                "conflict",
                format!("job has no result yet (status: {})", status.name()),
            )
            .to_response(),
        },
    }
}

fn healthz(ctx: &ApiCtx) -> Response {
    let counts = ctx.scheduler.counts();
    Response::json(
        200,
        &obj(vec![
            (
                "status",
                Json::Str(if ctx.scheduler.stopping() { "stopping" } else { "ok" }.into()),
            ),
            ("queued", Json::Num(counts.queued as f64)),
            ("running", Json::Num(counts.running as f64)),
            ("done", Json::Num(counts.done as f64)),
            ("failed", Json::Num(counts.failed as f64)),
            ("passes", Json::Num(ctx.scheduler.passes() as f64)),
            ("queue_depth", Json::Num(ctx.server.queue_depth as f64)),
            ("workers", Json::Num(ctx.server.workers as f64)),
        ]),
    )
}

/// `/v1/info` and `/v2/info` — the same canonical engine registry that
/// drives the CLI help, parse hints and `ising info`, plus the analytic
/// constants. Every row is generated from `config::ENGINES`: the name,
/// the paper section it reproduces, the accepted alias spellings (the
/// `/v1`-era string shim), and a `capabilities` object mirroring the
/// registry's flags (`runnable`, `farmable`, `snapshot`, `threads`).
fn info(ctx: &ApiCtx) -> Response {
    let engines: Vec<Json> = crate::config::ENGINES
        .iter()
        .map(|spec| {
            obj(vec![
                ("name", Json::Str(spec.name.to_string())),
                ("paper", Json::Str(spec.paper.to_string())),
                ("layout", Json::Str(spec.layout.to_string())),
                ("rng", Json::Str(spec.rng.to_string())),
                (
                    "aliases",
                    Json::Arr(
                        spec.aliases
                            .iter()
                            .map(|a| Json::Str(a.to_string()))
                            .collect(),
                    ),
                ),
                ("snapshot", Json::Bool(spec.snapshot)),
                ("needs_pjrt", Json::Bool(spec.needs_pjrt)),
                (
                    "capabilities",
                    obj(vec![
                        ("runnable", Json::Bool(spec.runnable)),
                        ("farmable", Json::Bool(spec.farmable)),
                        ("snapshot", Json::Bool(spec.snapshot)),
                        ("threads", Json::Bool(spec.threads)),
                    ]),
                ),
                // Kept for /v1 consumers; `capabilities.farmable` is the
                // v2 spelling of the same registry flag.
                ("farm", Json::Bool(spec.farmable)),
            ])
        })
        .collect();
    Response::json(
        200,
        &obj(vec![
            ("name", Json::Str("ising-dgx".into())),
            ("version", Json::Str(env!("CARGO_PKG_VERSION").into())),
            ("t_c", Json::Num(crate::analytic::critical_temperature())),
            ("beta_c", Json::Num(crate::analytic::critical_beta())),
            ("engines", Json::Arr(engines)),
            ("queue_depth", Json::Num(ctx.server.queue_depth as f64)),
            ("slice_samples", match ctx.server.slice_samples {
                Some(n) => Json::Num(n as f64),
                None => Json::Null,
            }),
        ]),
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::farm::default_beta_grid;
    use crate::server::http::read_request;
    use crate::server::queue::fingerprint;

    fn req(raw: &str) -> Request {
        read_request(&mut raw.as_bytes()).unwrap().unwrap()
    }

    /// `/v2` routes answer with the envelope + state machine; `/v1`
    /// keeps its legacy bodies but gains the deprecation headers.
    #[test]
    fn v2_routing_envelopes_and_v1_deprecation_shim() {
        let dir = std::env::temp_dir().join(format!("ising-api-v2-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let server = ServerConfig { checkpoint_dir: dir.clone(), ..ServerConfig::default() };
        let scheduler = Arc::new(Scheduler::open(&server).unwrap());
        let ctx = ApiCtx { scheduler, server };

        // v2 submit: 202 with the fine-grained state, no shim headers.
        let r = handle(&req("POST /v2/jobs HTTP/1.1\r\nContent-Length: 2\r\n\r\n{}"), &ctx);
        assert_eq!(r.status, 202);
        assert!(r.headers.is_empty(), "v2 must not carry deprecation headers");
        let body = Json::parse(std::str::from_utf8(&r.body).unwrap()).unwrap();
        assert_eq!(body.field("state").unwrap().as_str().unwrap(), "queued");
        let id = body.field("id").unwrap().as_str().unwrap().to_string();

        // v2 status: state machine position surfaced alongside status.
        let r = handle(&req(&format!("GET /v2/jobs/{id} HTTP/1.1\r\n\r\n")), &ctx);
        assert_eq!(r.status, 200);
        let body = Json::parse(std::str::from_utf8(&r.body).unwrap()).unwrap();
        assert_eq!(body.field("state").unwrap().as_str().unwrap(), "queued");
        assert_eq!(body.field("status").unwrap().as_str().unwrap(), "queued");

        // v2 result before completion: retryable conflict envelope.
        let r = handle(&req(&format!("GET /v2/jobs/{id}/result HTTP/1.1\r\n\r\n")), &ctx);
        assert_eq!(r.status, 409);
        let env = Json::parse(std::str::from_utf8(&r.body).unwrap()).unwrap();
        assert_eq!(env.field("code").unwrap().as_u64().unwrap(), 409);
        assert_eq!(env.field("kind").unwrap().as_str().unwrap(), "conflict");
        assert!(env.field("retryable").unwrap().as_bool().unwrap());

        // v2 invalid spec: non-retryable usage envelope.
        let bad = "POST /v2/jobs HTTP/1.1\r\nContent-Length: 13\r\n\r\n{\"sizes\": 64}";
        let r = handle(&req(bad), &ctx);
        assert_eq!(r.status, 400);
        let env = Json::parse(std::str::from_utf8(&r.body).unwrap()).unwrap();
        assert_eq!(env.field("kind").unwrap().as_str().unwrap(), "usage");
        assert!(!env.field("retryable").unwrap().as_bool().unwrap());

        // v2 unknown route: not_found envelope.
        let r = handle(&req("GET /v2/nope HTTP/1.1\r\n\r\n"), &ctx);
        assert_eq!(r.status, 404);
        let env = Json::parse(std::str::from_utf8(&r.body).unwrap()).unwrap();
        assert_eq!(env.field("kind").unwrap().as_str().unwrap(), "not_found");

        // v1: legacy body shape + advisory headers on every response.
        let r = handle(&req("GET /v1/healthz HTTP/1.1\r\n\r\n"), &ctx);
        assert_eq!(r.status, 200);
        assert!(r.headers.contains(&("Deprecation", "true".to_string())));
        assert!(r
            .headers
            .contains(&("Link", "</v2>; rel=\"successor-version\"".to_string())));
        let r = handle(&req("GET /v1/nope HTTP/1.1\r\n\r\n"), &ctx);
        assert_eq!(r.status, 404);
        assert!(r.headers.contains(&("Deprecation", "true".to_string())));
        let body = Json::parse(std::str::from_utf8(&r.body).unwrap()).unwrap();
        assert!(body.field("error").is_ok(), "v1 keeps the legacy error shape");

        let _ = std::fs::remove_dir_all(&dir);
    }

    /// `/v2/metrics` renders Prometheus text exposition with the
    /// scrape-time queue/job gauges, counts requests by status code,
    /// and refuses non-GET verbs.
    #[test]
    fn metrics_endpoint_serves_prometheus_exposition() {
        let dir = std::env::temp_dir().join(format!("ising-api-metrics-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let server = ServerConfig { checkpoint_dir: dir.clone(), ..ServerConfig::default() };
        let scheduler = Arc::new(Scheduler::open(&server).unwrap());
        let ctx = ApiCtx { scheduler, server };

        let r = handle(&req("GET /v2/metrics HTTP/1.1\r\n\r\n"), &ctx);
        assert_eq!(r.status, 200);
        assert_eq!(r.content_type, "text/plain; version=0.0.4");
        let text = String::from_utf8(r.body).unwrap();
        assert!(text.contains("# TYPE ising_queue_depth gauge\n"), "{text}");
        assert!(text.contains("ising_queue_depth 0\n"), "{text}");
        assert!(text.contains("ising_jobs{status=\"queued\"} 0\n"), "{text}");

        // The first scrape was counted; the second one sees it.
        let r = handle(&req("GET /v2/metrics HTTP/1.1\r\n\r\n"), &ctx);
        let text = String::from_utf8(r.body).unwrap();
        assert!(text.contains("ising_http_requests_total{code=\"200\"} 1\n"), "{text}");

        let r = handle(&req("POST /v2/metrics HTTP/1.1\r\n\r\n"), &ctx);
        assert_eq!(r.status, 405);
        let _ = std::fs::remove_dir_all(&dir);
    }

    /// The `/v2/artifacts` surface end to end over `handle`: blob push
    /// (verified), probe, pull; manifest push + tag; tag listing; and
    /// the digest-mismatch rejection that makes transfers trustworthy.
    #[test]
    fn artifacts_routes_push_probe_pull_and_reject_mismatches() {
        use crate::registry::manifest::SPEC_MEDIA_TYPE;
        use crate::registry::{digest_of, Descriptor};

        let dir = std::env::temp_dir().join(format!("ising-api-art-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let server = ServerConfig { checkpoint_dir: dir.clone(), ..ServerConfig::default() };
        let scheduler = Arc::new(Scheduler::open(&server).unwrap());
        let ctx = ApiCtx { scheduler, server };
        let put = |path: &str, body: &[u8]| {
            let mut r = Request::new("PUT", path);
            r.body = body.to_vec();
            handle(&r, &ctx)
        };

        // Push a blob under its true digest; wrong digest is refused.
        let payload = b"replica snapshot bytes";
        let digest = digest_of(payload);
        let bogus = digest_of(b"other bytes");
        let r = put(&format!("/v2/artifacts/blobs/{bogus}"), payload);
        assert_eq!(r.status, 400);
        let env = Json::parse(std::str::from_utf8(&r.body).unwrap()).unwrap();
        assert_eq!(env.field("kind").unwrap().as_str().unwrap(), "usage");
        let r = put(&format!("/v2/artifacts/blobs/{digest}"), payload);
        assert_eq!(r.status, 200);

        // Probe + pull: HEAD carries the size, GET the verbatim bytes.
        let r = handle(&Request::new("HEAD", &format!("/v2/artifacts/blobs/{digest}")), &ctx);
        assert_eq!(r.status, 200);
        assert!(r.headers.contains(&("X-Blob-Size", payload.len().to_string())));
        assert!(r.body.is_empty());
        let r = handle(&Request::new("GET", &format!("/v2/artifacts/blobs/{digest}")), &ctx);
        assert_eq!(r.status, 200);
        assert_eq!(r.content_type, "application/octet-stream");
        assert_eq!(r.body, payload);
        let r = handle(&Request::new("HEAD", &format!("/v2/artifacts/blobs/{bogus}")), &ctx);
        assert_eq!(r.status, 404);

        // A manifest referencing the blob, pushed to a tag.
        let m = Manifest::new(Descriptor::for_bytes(SPEC_MEDIA_TYPE, payload), vec![]);
        let r = put("/v2/artifacts/manifests/demo/ckpt", &m.canonical_bytes());
        assert_eq!(r.status, 200, "{:?}", String::from_utf8_lossy(&r.body));
        let body = Json::parse(std::str::from_utf8(&r.body).unwrap()).unwrap();
        assert_eq!(body.field("digest").unwrap().as_str().unwrap(), m.digest());

        // Pull it back by tag: canonical bytes, digest echoed in a header.
        let r = handle(&Request::new("GET", "/v2/artifacts/manifests/demo/ckpt"), &ctx);
        assert_eq!(r.status, 200);
        assert_eq!(r.body, m.canonical_bytes());
        assert!(r.headers.contains(&("Docker-Content-Digest", m.digest())));
        // Unknown refs are 404 envelopes.
        let r = handle(&Request::new("GET", "/v2/artifacts/manifests/no/such/tag"), &ctx);
        assert_eq!(r.status, 404);

        // A manifest whose blobs were never pushed is a sequencing error.
        let orphan =
            Manifest::new(Descriptor::for_bytes(SPEC_MEDIA_TYPE, b"never pushed"), vec![]);
        let r = put("/v2/artifacts/manifests/demo/orphan", &orphan.canonical_bytes());
        assert_eq!(r.status, 400);

        // Tags listing sees the pushed tag.
        let r = handle(&Request::new("GET", "/v2/artifacts/tags"), &ctx);
        assert_eq!(r.status, 200);
        let tags = Json::parse(std::str::from_utf8(&r.body).unwrap()).unwrap();
        let names: Vec<String> = tags
            .field("tags")
            .unwrap()
            .as_arr()
            .unwrap()
            .iter()
            .map(|t| t.field("name").unwrap().as_str().unwrap().to_string())
            .collect();
        assert!(names.contains(&"demo/ckpt".to_string()), "{names:?}");

        // Wrong verbs are 405, not 404.
        let r = handle(&Request::new("POST", "/v2/artifacts/tags"), &ctx);
        assert_eq!(r.status, 405);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn job_spec_defaults_mirror_the_sweep_cli() {
        let cfg = job_config_from_json(&Json::parse("{}").unwrap()).unwrap();
        assert_eq!(cfg.geom.h, 256);
        assert_eq!(cfg.engine, FarmEngine::Multispin);
        assert_eq!(cfg.betas, default_beta_grid(4));
        assert_eq!(cfg.seeds, vec![1]);
        assert_eq!(cfg.workers, 1);
        assert_eq!(cfg.shards, 1);
        assert_eq!(cfg.threads, 1);
        assert!(!cfg.threaded_shards);
    }

    #[test]
    fn job_spec_full_parse() {
        let doc = Json::parse(
            r#"{"size": 64, "engine": "tensor", "betas": [0.42, 0.46], "replicas": 3,
                "seed": 7, "burn_in": 11, "samples": 13, "thin": 2, "workers": 2}"#,
        )
        .unwrap();
        let cfg = job_config_from_json(&doc).unwrap();
        assert_eq!(cfg.geom.h, 64);
        assert_eq!(cfg.engine, FarmEngine::Tensor);
        assert_eq!(cfg.betas, vec![0.42f32, 0.46]);
        assert_eq!(cfg.seeds, vec![7, 8, 9]);
        assert_eq!(cfg.burn_in, 11);
        assert_eq!(cfg.samples, 13);
        assert_eq!(cfg.thin, 2);
        assert_eq!(cfg.workers, 2);
    }

    #[test]
    fn job_spec_rejections() {
        for bad in [
            r#"[]"#,                                        // not an object
            r#"{"sizes": 64}"#,                             // unknown key
            r#"{"engine": "wolff"}"#,                       // non-farm engine
            r#"{"engine": "tensor-fp16"}"#,                 // refused precision
            r#"{"betas": []}"#,                             // empty grid
            r#"{"betas": [0.0]}"#,                          // unphysical β
            r#"{"betas": [-1]}"#,                           // unphysical β
            r#"{"betas": "0.4"}"#,                          // wrong type
            r#"{"size": 63}"#,                              // odd size
            r#"{"size": 48}"#,                              // multispin % 32
            r#"{"size": 64, "workers": 0}"#,                // zero workers
            r#"{"size": 64, "shards": 0}"#,                 // zero shards
            r#"{"size": 64, "samples": 0}"#,                // zero samples
            r#"{"size": 64, "seed": 4294967296}"#,          // seed > u32
            r#"{"size": 64, "engine": "tensor", "shards": 2}"#, // tensor sharding
            r#"{"size": -64}"#,                             // negative size
        ] {
            let doc = Json::parse(bad).unwrap();
            assert!(job_config_from_json(&doc).is_err(), "must reject: {bad}");
        }
        // Tensor has no %32 constraint: 48 is fine there.
        let ok = Json::parse(r#"{"size": 48, "engine": "tensor"}"#).unwrap();
        assert_eq!(job_config_from_json(&ok).unwrap().geom.h, 48);
    }

    /// The batch engine submits like any farm engine, under the same
    /// shared validation: sharding refused, no %32 width constraint,
    /// aliases resolved by the canonical registry.
    #[test]
    fn job_spec_accepts_the_batch_engine() {
        let doc = Json::parse(
            r#"{"size": 48, "engine": "batch", "betas": [0.44], "replicas": 80,
                "samples": 4}"#,
        )
        .unwrap();
        let cfg = job_config_from_json(&doc).unwrap();
        assert_eq!(cfg.engine, FarmEngine::Batch);
        assert_eq!(cfg.geom.h, 48);
        assert_eq!(cfg.seeds.len(), 80);
        let alias = Json::parse(r#"{"size": 64, "engine": "batch64"}"#).unwrap();
        assert_eq!(job_config_from_json(&alias).unwrap().engine, FarmEngine::Batch);
        // Sharding knobs are refused by the shared FarmConfig::validate.
        let bad = Json::parse(r#"{"size": 64, "engine": "batch", "shards": 2}"#).unwrap();
        assert!(job_config_from_json(&bad).is_err());
    }

    /// The domain engine submits with its slab thread count — via the
    /// typed engine object or the flat v1-style key — and `threads`
    /// stays execution layout, outside the job fingerprint.
    #[test]
    fn job_spec_accepts_domain_with_threads() {
        let typed = job_config_from_json(
            &Json::parse(
                r#"{"size": 64, "engine": {"kind": "domain", "threads": 4},
                    "betas": [0.44], "samples": 3}"#,
            )
            .unwrap(),
        )
        .unwrap();
        assert_eq!(typed.engine, FarmEngine::Domain);
        assert_eq!(typed.threads, 4);
        let flat = job_config_from_json(
            &Json::parse(
                r#"{"size": 64, "engine": "domain", "threads": 4,
                    "betas": [0.44], "samples": 3}"#,
            )
            .unwrap(),
        )
        .unwrap();
        assert_eq!(flat.threads, 4);
        assert_eq!(fingerprint(&typed), fingerprint(&flat));
        // Thread count is layout, not physics: same key at 1 thread.
        let single = job_config_from_json(
            &Json::parse(r#"{"size": 64, "engine": "domain", "betas": [0.44], "samples": 3}"#)
                .unwrap(),
        )
        .unwrap();
        assert_eq!(single.threads, 1);
        assert_eq!(fingerprint(&typed), fingerprint(&single));
        for bad in [
            // threads is a domain-only knob
            r#"{"size": 64, "engine": "multispin", "threads": 2}"#,
            // 64 rows cannot split into 3 even slabs
            r#"{"size": 64, "engine": "domain", "threads": 3}"#,
            // legal split, but over the service worker cap
            r#"{"size": 256, "engine": "domain", "threads": 128}"#,
        ] {
            let doc = Json::parse(bad).unwrap();
            assert!(job_config_from_json(&doc).is_err(), "must reject: {bad}");
        }
    }

    /// `/v2/info` serves the engine capability matrix straight from the
    /// canonical registry: names, paper sections, alias shims (the
    /// `/v1`-era string spellings) and capability flags match
    /// `config::ENGINES` row for row.
    #[test]
    fn info_matrix_mirrors_the_engine_registry() {
        let dir = std::env::temp_dir().join(format!("ising-api-info-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let server = ServerConfig { checkpoint_dir: dir.clone(), ..ServerConfig::default() };
        let scheduler = Arc::new(Scheduler::open(&server).unwrap());
        let ctx = ApiCtx { scheduler, server };

        let r = handle(&Request::new("GET", "/v2/info"), &ctx);
        assert_eq!(r.status, 200);
        let body = Json::parse(std::str::from_utf8(&r.body).unwrap()).unwrap();
        let rows = body.field("engines").unwrap().as_arr().unwrap();
        assert_eq!(rows.len(), crate::config::ENGINES.len());
        for (row, spec) in rows.iter().zip(crate::config::ENGINES) {
            assert_eq!(row.field("name").unwrap().as_str().unwrap(), spec.name);
            assert_eq!(row.field("paper").unwrap().as_str().unwrap(), spec.paper);
            let caps = row.field("capabilities").unwrap();
            for (key, flag) in [
                ("runnable", spec.runnable),
                ("farmable", spec.farmable),
                ("snapshot", spec.snapshot),
                ("threads", spec.threads),
            ] {
                assert_eq!(
                    caps.field(key).unwrap().as_bool().unwrap(),
                    flag,
                    "capability {key} of engine {}",
                    spec.name
                );
            }
            let aliases: Vec<&str> = row
                .field("aliases")
                .unwrap()
                .as_arr()
                .unwrap()
                .iter()
                .map(|a| a.as_str().unwrap())
                .collect();
            assert_eq!(aliases, spec.aliases.to_vec(), "aliases of {}", spec.name);
        }
        // Spot-check the rows the matrix exists to communicate: only
        // domain honours --threads; wolff runs but does not farm.
        let find = |name: &str| {
            rows.iter()
                .find(|r| r.field("name").unwrap().as_str().unwrap() == name)
                .unwrap()
        };
        let domain_caps = find("domain").field("capabilities").unwrap();
        assert!(domain_caps.field("threads").unwrap().as_bool().unwrap());
        let wolff_caps = find("wolff").field("capabilities").unwrap();
        assert!(wolff_caps.field("runnable").unwrap().as_bool().unwrap());
        assert!(!wolff_caps.field("farmable").unwrap().as_bool().unwrap());
        let _ = std::fs::remove_dir_all(&dir);
    }

    /// One request must not be able to OOM the server: the service caps
    /// reject allocation-scale inputs at submit time (400, not a
    /// crash-looping persisted job).
    #[test]
    fn job_spec_resource_caps() {
        use crate::server::queue::limits;
        for bad in [
            format!(r#"{{"size": {}}}"#, (limits::MAX_SIZE + 2).next_multiple_of(32)),
            format!(r#"{{"size": 64, "samples": {}}}"#, limits::MAX_SAMPLES + 1),
            format!(r#"{{"size": 64, "replicas": {}}}"#, limits::MAX_REPLICAS + 1),
            format!(r#"{{"size": 64, "workers": {}}}"#, limits::MAX_WORKERS + 1),
            format!(r#"{{"size": 64, "shards": {}}}"#, limits::MAX_WORKERS + 1),
            // Individually legal, jointly over the series cap.
            format!(
                r#"{{"size": 64, "betas": [0.44], "replicas": {}, "samples": {}}}"#,
                limits::MAX_REPLICAS,
                limits::MAX_SAMPLES
            ),
        ] {
            let doc = Json::parse(&bad).unwrap();
            let err = job_config_from_json(&doc).unwrap_err().to_string();
            assert!(err.contains("cap"), "must cap-reject {bad}: {err}");
        }
        // The caps leave the realistic paper regime untouched.
        let ok = Json::parse(r#"{"size": 4096, "replicas": 8, "samples": 2000}"#).unwrap();
        assert!(job_config_from_json(&ok).is_ok());
    }

    #[test]
    fn equivalent_specs_share_a_fingerprint() {
        let a = job_config_from_json(
            &Json::parse(r#"{"size": 64, "betas": [0.44], "samples": 5}"#).unwrap(),
        )
        .unwrap();
        // Different execution layout, same physics: same job key.
        let b = job_config_from_json(
            &Json::parse(
                r#"{"size": 64, "betas": [0.44], "samples": 5, "workers": 4, "shards": 2}"#,
            )
            .unwrap(),
        )
        .unwrap();
        assert_eq!(fingerprint(&a), fingerprint(&b));
    }
}
