//! The `/v1` JSON job API: request routing + the submit-spec ↔
//! `FarmConfig` mapping.
//!
//! | Method | Path                  | Meaning                               |
//! |--------|-----------------------|---------------------------------------|
//! | POST   | `/v1/jobs`            | submit a sweep job (JSON body)        |
//! | GET    | `/v1/jobs/{id}`       | job status                            |
//! | GET    | `/v1/jobs/{id}/result`| bit-exact replica report (text/plain) |
//! | GET    | `/v1/healthz`         | liveness + queue/registry counts      |
//! | GET    | `/v1/info`            | engine matrix + analytic constants    |
//! | POST   | `/v1/shutdown`        | graceful stop (checkpoints in-flight) |
//!
//! The submit body carries the same TOML-equivalent sweep configuration
//! the `ising sweep` CLI takes (`size`, `engine`, `betas`/`beta_points`,
//! `replicas`, `seed`, `burn_in`, `samples`, `thin`, `workers`,
//! `shards`), validated with the same rules. The result body is the
//! exact byte string `ising sweep --report` writes for the same config.

use super::http::{Request, Response};
use super::queue::{Scheduler, Submit};
use crate::config::ServerConfig;
use crate::coordinator::farm::{default_beta_grid, FarmConfig, FarmEngine};
use crate::error::{Error, Result};
use crate::util::json::{obj, Json};
use std::sync::Arc;

/// Shared handler context.
pub struct ApiCtx {
    /// The job scheduler (also carries the stop flag the shutdown
    /// endpoint raises).
    pub scheduler: Arc<Scheduler>,
    /// Serving configuration (echoed by the health endpoint).
    pub server: ServerConfig,
}

/// Parse a submitted job spec (the POST `/v1/jobs` body) into a farm
/// configuration. JSON shape (known keys, types, value ranges) is
/// checked here; the semantic rules — finite positive β,
/// engine/geometry compatibility, workers/shards ≥ 1 — are
/// [`FarmConfig::validate`], the *same* function the `ising sweep` CLI
/// and the farm itself call, so the entry points cannot drift.
pub fn job_config_from_json(doc: &Json) -> Result<FarmConfig> {
    const KNOWN: &[&str] = &[
        "size", "engine", "betas", "beta_points", "replicas", "seed", "burn_in",
        "samples", "thin", "workers", "shards",
    ];
    let fields = doc.as_obj().map_err(|_| Error::Usage("job spec must be a JSON object".into()))?;
    for key in fields.keys() {
        if !KNOWN.contains(&key.as_str()) {
            return Err(Error::Usage(format!(
                "unknown job key '{key}' (known: {})",
                KNOWN.join(", ")
            )));
        }
    }
    let get_u64 = |key: &str, default: u64| -> Result<u64> {
        match doc.get(key) {
            None => Ok(default),
            Some(v) => v
                .as_u64()
                .map_err(|_| Error::Usage(format!("job key '{key}' must be a non-negative integer"))),
        }
    };

    let size = get_u64("size", 256)? as usize;
    let engine = match doc.get("engine") {
        None => FarmEngine::Multispin,
        Some(v) => FarmEngine::parse(
            v.as_str().map_err(|_| Error::Usage("job key 'engine' must be a string".into()))?,
        )?,
    };
    let betas: Vec<f32> = match doc.get("betas") {
        Some(v) => {
            let arr = v
                .as_arr()
                .map_err(|_| Error::Usage("job key 'betas' must be an array of numbers".into()))?;
            let mut betas = Vec::with_capacity(arr.len());
            for item in arr {
                let b = item.as_f64().map_err(|_| {
                    Error::Usage("job key 'betas' must be an array of numbers".into())
                })? as f32;
                betas.push(b);
            }
            betas
        }
        None => {
            // Cap before generating: a huge beta_points must fail with a
            // 400, not an allocation.
            let n = get_u64("beta_points", 4)?.max(1) as usize;
            if n > super::queue::limits::MAX_BETAS {
                return Err(Error::Usage(format!(
                    "{n} beta_points exceed the service cap of {}",
                    super::queue::limits::MAX_BETAS
                )));
            }
            default_beta_grid(n)
        }
    };
    // Same pre-allocation cap for the seed grid `FarmConfig::grid` builds.
    let replicas = get_u64("replicas", 1)?.max(1) as usize;
    if replicas > super::queue::limits::MAX_REPLICAS {
        return Err(Error::Usage(format!(
            "{replicas} replicas exceed the service cap of {}",
            super::queue::limits::MAX_REPLICAS
        )));
    }
    let seed = u32::try_from(get_u64("seed", 1)?)
        .map_err(|_| Error::Usage("job key 'seed' must fit in u32".into()))?;

    let mut cfg = FarmConfig::grid(size, betas, replicas, seed)?;
    cfg.engine = engine;
    cfg.burn_in = get_u64("burn_in", cfg.burn_in)?;
    cfg.samples = get_u64("samples", cfg.samples as u64)? as usize;
    cfg.thin = get_u64("thin", cfg.thin)?;
    cfg.workers = get_u64("workers", 1)? as usize;
    cfg.shards = get_u64("shards", 1)? as usize;

    // The shared semantic rules (FarmConfig::validate): finite positive
    // β, samples/workers/shards ≥ 1, per-engine geometry and sharding
    // constraints — identical to the `ising sweep` CLI, so submitters
    // get a 400 preflight instead of a failed job.
    cfg.validate()?;
    // Service resource caps: one request must not be able to OOM the
    // server (the scheduler re-checks these as a backstop).
    super::queue::enforce_job_limits(&cfg)?;
    Ok(cfg)
}

/// Route one request. Infallible by construction: every failure becomes
/// a status-coded JSON body.
pub fn handle(req: &Request, ctx: &ApiCtx) -> Response {
    let segs: Vec<&str> = req.path.split('/').filter(|s| !s.is_empty()).collect();
    match (req.method.as_str(), segs.as_slice()) {
        ("POST", ["v1", "jobs"]) => submit(req, ctx),
        ("GET", ["v1", "jobs", id]) => job_status(id, ctx),
        ("GET", ["v1", "jobs", id, "result"]) => job_result(id, ctx),
        ("GET", ["v1", "healthz"]) => healthz(ctx),
        ("GET", ["v1", "info"]) => info(ctx),
        ("POST", ["v1", "shutdown"]) => {
            ctx.scheduler.request_stop();
            Response::json(200, &obj(vec![("status", Json::Str("stopping".into()))]))
        }
        // Known paths with the wrong verb get 405, everything else 404.
        (_, ["v1", "jobs"]) | (_, ["v1", "shutdown"]) => error_response(
            405,
            "use POST for this endpoint",
        ),
        (_, ["v1", "jobs", _]) | (_, ["v1", "jobs", _, "result"])
        | (_, ["v1", "healthz"]) | (_, ["v1", "info"]) => {
            error_response(405, "use GET for this endpoint")
        }
        _ => error_response(404, &format!("no route for '{}'", req.path)),
    }
}

fn error_response(status: u16, msg: &str) -> Response {
    Response::json(status, &obj(vec![("error", Json::Str(msg.to_string()))]))
}

fn submit(req: &Request, ctx: &ApiCtx) -> Response {
    let body = match req.body_str() {
        Ok(s) => s,
        Err(e) => return e.into_response(),
    };
    let doc = match Json::parse(body) {
        Ok(d) => d,
        Err(e) => return error_response(400, &format!("invalid JSON body: {e}")),
    };
    let cfg = match job_config_from_json(&doc) {
        Ok(c) => c,
        Err(e) => return error_response(400, &e.to_string()),
    };
    match ctx.scheduler.submit(cfg) {
        Ok(Submit::Accepted { id }) => Response::json(
            202,
            &obj(vec![
                ("id", Json::Str(id)),
                ("status", Json::Str("queued".into())),
            ]),
        ),
        Ok(Submit::Existing { id, status }) => Response::json(
            200,
            &obj(vec![
                ("id", Json::Str(id)),
                ("status", Json::Str(status.name().into())),
            ]),
        ),
        Ok(Submit::Busy) => error_response(
            429,
            &format!(
                "job queue full (depth {}) or shutting down; retry later",
                ctx.server.queue_depth
            ),
        ),
        // The scheduler's own validation backstop is caller error (400);
        // anything else (I/O on the job store) is genuinely ours (500).
        Err(Error::Usage(msg)) => error_response(400, &msg),
        Err(e) => error_response(500, &e.to_string()),
    }
}

fn job_status(id: &str, ctx: &ApiCtx) -> Response {
    if !super::cache::is_valid_id(id) {
        return error_response(400, "job id must be 16 lowercase hex characters");
    }
    match ctx.scheduler.job_summary(id) {
        None => error_response(404, &format!("unknown job '{id}'")),
        Some((status, engine, replicas, samples)) => {
            let mut fields = vec![
                ("id", Json::Str(id.to_string())),
                ("status", Json::Str(status.name().into())),
                ("engine", Json::Str(engine)),
                ("replicas", Json::Num(replicas as f64)),
                ("samples_per_replica", Json::Num(samples as f64)),
            ];
            if let super::queue::JobStatus::Failed(msg) = &status {
                fields.push(("error", Json::Str(msg.clone())));
            }
            Response::json(200, &obj(fields))
        }
    }
}

fn job_result(id: &str, ctx: &ApiCtx) -> Response {
    if !super::cache::is_valid_id(id) {
        return error_response(400, "job id must be 16 lowercase hex characters");
    }
    match ctx.scheduler.status(id) {
        None => error_response(404, &format!("unknown job '{id}'")),
        Some(status) => match ctx.scheduler.result(id) {
            // Byte-identical to `ising sweep --report` for this config.
            Some(report) => Response::text(200, report),
            None => Response::json(
                409,
                &obj(vec![
                    ("id", Json::Str(id.to_string())),
                    ("status", Json::Str(status.name().into())),
                    ("error", Json::Str("job has no result yet".into())),
                ]),
            ),
        },
    }
}

fn healthz(ctx: &ApiCtx) -> Response {
    let counts = ctx.scheduler.counts();
    Response::json(
        200,
        &obj(vec![
            (
                "status",
                Json::Str(if ctx.scheduler.stopping() { "stopping" } else { "ok" }.into()),
            ),
            ("queued", Json::Num(counts.queued as f64)),
            ("running", Json::Num(counts.running as f64)),
            ("done", Json::Num(counts.done as f64)),
            ("failed", Json::Num(counts.failed as f64)),
            ("passes", Json::Num(ctx.scheduler.passes() as f64)),
            ("queue_depth", Json::Num(ctx.server.queue_depth as f64)),
            ("workers", Json::Num(ctx.server.workers as f64)),
        ]),
    )
}

/// `/v1/info` — the same canonical engine registry that drives the CLI
/// help, parse hints and `ising info`, plus the analytic constants.
fn info(ctx: &ApiCtx) -> Response {
    let engines: Vec<Json> = crate::config::ENGINES
        .iter()
        .map(|spec| {
            obj(vec![
                ("name", Json::Str(spec.name.to_string())),
                ("paper", Json::Str(spec.paper.to_string())),
                ("layout", Json::Str(spec.layout.to_string())),
                ("rng", Json::Str(spec.rng.to_string())),
                ("snapshot", Json::Bool(spec.snapshot)),
                ("needs_pjrt", Json::Bool(spec.needs_pjrt)),
                (
                    "farm",
                    Json::Bool(FarmEngine::parse(spec.name).is_ok()),
                ),
            ])
        })
        .collect();
    Response::json(
        200,
        &obj(vec![
            ("name", Json::Str("ising-dgx".into())),
            ("version", Json::Str(env!("CARGO_PKG_VERSION").into())),
            ("t_c", Json::Num(crate::analytic::critical_temperature())),
            ("beta_c", Json::Num(crate::analytic::critical_beta())),
            ("engines", Json::Arr(engines)),
            ("queue_depth", Json::Num(ctx.server.queue_depth as f64)),
            ("slice_samples", match ctx.server.slice_samples {
                Some(n) => Json::Num(n as f64),
                None => Json::Null,
            }),
        ]),
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::server::queue::fingerprint;

    #[test]
    fn job_spec_defaults_mirror_the_sweep_cli() {
        let cfg = job_config_from_json(&Json::parse("{}").unwrap()).unwrap();
        assert_eq!(cfg.geom.h, 256);
        assert_eq!(cfg.engine, FarmEngine::Multispin);
        assert_eq!(cfg.betas, default_beta_grid(4));
        assert_eq!(cfg.seeds, vec![1]);
        assert_eq!(cfg.workers, 1);
        assert_eq!(cfg.shards, 1);
        assert!(!cfg.threaded_shards);
    }

    #[test]
    fn job_spec_full_parse() {
        let doc = Json::parse(
            r#"{"size": 64, "engine": "tensor", "betas": [0.42, 0.46], "replicas": 3,
                "seed": 7, "burn_in": 11, "samples": 13, "thin": 2, "workers": 2}"#,
        )
        .unwrap();
        let cfg = job_config_from_json(&doc).unwrap();
        assert_eq!(cfg.geom.h, 64);
        assert_eq!(cfg.engine, FarmEngine::Tensor);
        assert_eq!(cfg.betas, vec![0.42f32, 0.46]);
        assert_eq!(cfg.seeds, vec![7, 8, 9]);
        assert_eq!(cfg.burn_in, 11);
        assert_eq!(cfg.samples, 13);
        assert_eq!(cfg.thin, 2);
        assert_eq!(cfg.workers, 2);
    }

    #[test]
    fn job_spec_rejections() {
        for bad in [
            r#"[]"#,                                        // not an object
            r#"{"sizes": 64}"#,                             // unknown key
            r#"{"engine": "wolff"}"#,                       // non-farm engine
            r#"{"engine": "tensor-fp16"}"#,                 // refused precision
            r#"{"betas": []}"#,                             // empty grid
            r#"{"betas": [0.0]}"#,                          // unphysical β
            r#"{"betas": [-1]}"#,                           // unphysical β
            r#"{"betas": "0.4"}"#,                          // wrong type
            r#"{"size": 63}"#,                              // odd size
            r#"{"size": 48}"#,                              // multispin % 32
            r#"{"size": 64, "workers": 0}"#,                // zero workers
            r#"{"size": 64, "shards": 0}"#,                 // zero shards
            r#"{"size": 64, "samples": 0}"#,                // zero samples
            r#"{"size": 64, "seed": 4294967296}"#,          // seed > u32
            r#"{"size": 64, "engine": "tensor", "shards": 2}"#, // tensor sharding
            r#"{"size": -64}"#,                             // negative size
        ] {
            let doc = Json::parse(bad).unwrap();
            assert!(job_config_from_json(&doc).is_err(), "must reject: {bad}");
        }
        // Tensor has no %32 constraint: 48 is fine there.
        let ok = Json::parse(r#"{"size": 48, "engine": "tensor"}"#).unwrap();
        assert_eq!(job_config_from_json(&ok).unwrap().geom.h, 48);
    }

    /// The batch engine submits like any farm engine, under the same
    /// shared validation: sharding refused, no %32 width constraint,
    /// aliases resolved by the canonical registry.
    #[test]
    fn job_spec_accepts_the_batch_engine() {
        let doc = Json::parse(
            r#"{"size": 48, "engine": "batch", "betas": [0.44], "replicas": 80,
                "samples": 4}"#,
        )
        .unwrap();
        let cfg = job_config_from_json(&doc).unwrap();
        assert_eq!(cfg.engine, FarmEngine::Batch);
        assert_eq!(cfg.geom.h, 48);
        assert_eq!(cfg.seeds.len(), 80);
        let alias = Json::parse(r#"{"size": 64, "engine": "batch64"}"#).unwrap();
        assert_eq!(job_config_from_json(&alias).unwrap().engine, FarmEngine::Batch);
        // Sharding knobs are refused by the shared FarmConfig::validate.
        let bad = Json::parse(r#"{"size": 64, "engine": "batch", "shards": 2}"#).unwrap();
        assert!(job_config_from_json(&bad).is_err());
    }

    /// One request must not be able to OOM the server: the service caps
    /// reject allocation-scale inputs at submit time (400, not a
    /// crash-looping persisted job).
    #[test]
    fn job_spec_resource_caps() {
        use crate::server::queue::limits;
        for bad in [
            format!(r#"{{"size": {}}}"#, (limits::MAX_SIZE + 2).next_multiple_of(32)),
            format!(r#"{{"size": 64, "samples": {}}}"#, limits::MAX_SAMPLES + 1),
            format!(r#"{{"size": 64, "replicas": {}}}"#, limits::MAX_REPLICAS + 1),
            format!(r#"{{"size": 64, "workers": {}}}"#, limits::MAX_WORKERS + 1),
            format!(r#"{{"size": 64, "shards": {}}}"#, limits::MAX_WORKERS + 1),
            // Individually legal, jointly over the series cap.
            format!(
                r#"{{"size": 64, "betas": [0.44], "replicas": {}, "samples": {}}}"#,
                limits::MAX_REPLICAS,
                limits::MAX_SAMPLES
            ),
        ] {
            let doc = Json::parse(&bad).unwrap();
            let err = job_config_from_json(&doc).unwrap_err().to_string();
            assert!(err.contains("cap"), "must cap-reject {bad}: {err}");
        }
        // The caps leave the realistic paper regime untouched.
        let ok = Json::parse(r#"{"size": 4096, "replicas": 8, "samples": 2000}"#).unwrap();
        assert!(job_config_from_json(&ok).is_ok());
    }

    #[test]
    fn equivalent_specs_share_a_fingerprint() {
        let a = job_config_from_json(
            &Json::parse(r#"{"size": 64, "betas": [0.44], "samples": 5}"#).unwrap(),
        )
        .unwrap();
        // Different execution layout, same physics: same job key.
        let b = job_config_from_json(
            &Json::parse(
                r#"{"size": 64, "betas": [0.44], "samples": 5, "workers": 4, "shards": 2}"#,
            )
            .unwrap(),
        )
        .unwrap();
        assert_eq!(fingerprint(&a), fingerprint(&b));
    }
}
