//! Versioned artifact manifests — the registry's metadata documents.
//!
//! A manifest is a small JSON document modeled on the OCI image
//! manifest: a schema version, its own media type, one `config`
//! descriptor, and a list of `layers` descriptors. Every descriptor is
//! `{mediaType, digest, size}` (plus optional string annotations, used
//! to carry checkpoint file names), and every digest is a
//! `sha256:<hex>` address into the blob store. A farm checkpoint
//! becomes a layered artifact this way: the farm manifest (`farm.json`)
//! is the config layer and each replica/unit snapshot is one blob
//! layer, so two jobs sharing a run prefix share their common snapshot
//! blobs byte-for-byte.
//!
//! Parsing is strict, like the `/v2` wire messages: unknown fields,
//! malformed digests, and oversized documents are rejected — a manifest
//! that round-trips is exactly the manifest that was written. The
//! canonical byte form (compact JSON, `BTreeMap`-sorted keys) is what
//! gets digested, so a manifest's address is deterministic.

use crate::error::{Error, Result};
use crate::util::json::{obj, Json};
use std::collections::BTreeMap;

use super::digest::{digest_of, is_valid_digest};

/// Manifest schema version this build reads and writes.
pub const SCHEMA_VERSION: usize = 1;

/// Media type of the manifest document itself.
pub const MANIFEST_MEDIA_TYPE: &str = "application/vnd.ising.artifact.manifest.v1+json";
/// Media type of a farm checkpoint manifest (`farm.json`) config layer.
pub const FARM_CONFIG_MEDIA_TYPE: &str = "application/vnd.ising.farm.manifest.v1+json";
/// Media type of one replica/unit snapshot blob (an `ISNGSNAP`
/// container, CRC framing included — the registry digest covers it).
pub const SNAPSHOT_MEDIA_TYPE: &str = "application/vnd.ising.replica.snapshot.v1";
/// Media type of a canonical job spec (`job.json`) config layer.
pub const SPEC_MEDIA_TYPE: &str = "application/vnd.ising.job.spec.v1+json";
/// Media type of a finished job's replica report (`result.txt` bytes).
pub const REPORT_MEDIA_TYPE: &str = "application/vnd.ising.replica.report.v1";

/// Descriptor annotation key carrying a checkpoint file name, so a
/// pulled artifact can be materialized back into a checkpoint dir.
pub const NAME_ANNOTATION: &str = "org.ising.name";

/// Layer-count cap (a 4-unit fleet writes 4; a hostile manifest does
/// not get to allocate unbounded descriptors).
pub const MAX_LAYERS: usize = 4096;
/// Annotation caps per map and per string.
pub const MAX_ANNOTATIONS: usize = 64;
/// Longest accepted media type / annotation string.
pub const MAX_STRING: usize = 256;

/// Reject unknown fields the same way the `/v2` wire decoders do, so a
/// manifest never silently drops data it does not understand.
fn strict_keys(doc: &Json, what: &str, allowed: &[&str]) -> Result<()> {
    for key in doc.as_obj()?.keys() {
        if !allowed.contains(&key.as_str()) {
            return Err(Error::Artifact(format!("unknown {what} field '{key}'")));
        }
    }
    Ok(())
}

fn check_media_type(s: &str, what: &str) -> Result<()> {
    if s.is_empty() || s.len() > MAX_STRING || !s.contains('/') {
        return Err(Error::Artifact(format!("{what}: malformed mediaType '{s}'")));
    }
    Ok(())
}

fn parse_annotations(doc: &Json) -> Result<BTreeMap<String, String>> {
    let fields = doc.as_obj()?;
    if fields.len() > MAX_ANNOTATIONS {
        return Err(Error::Artifact(format!(
            "too many annotations ({} > {MAX_ANNOTATIONS})",
            fields.len()
        )));
    }
    let mut out = BTreeMap::new();
    for (key, value) in fields {
        let value = value.as_str()?;
        if key.is_empty() || key.len() > MAX_STRING || value.len() > MAX_STRING {
            return Err(Error::Artifact(format!("oversized annotation '{key}'")));
        }
        out.insert(key.clone(), value.to_string());
    }
    Ok(out)
}

fn annotations_json(annotations: &BTreeMap<String, String>) -> Json {
    Json::Obj(
        annotations
            .iter()
            .map(|(k, v)| (k.clone(), Json::Str(v.clone())))
            .collect(),
    )
}

/// One content-addressed reference: what the bytes are (`media_type`),
/// where they live (`digest`), and how many there are (`size`).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Descriptor {
    /// Media type of the referenced blob.
    pub media_type: String,
    /// Blob address (`sha256:<64 hex>`).
    pub digest: String,
    /// Blob length in bytes (verified against the stored blob on pull).
    pub size: u64,
    /// Optional string annotations (e.g. [`NAME_ANNOTATION`]).
    pub annotations: BTreeMap<String, String>,
}

impl Descriptor {
    /// Descriptor for `bytes` under `media_type` (digest computed here).
    pub fn for_bytes(media_type: &str, bytes: &[u8]) -> Self {
        Self {
            media_type: media_type.to_string(),
            digest: digest_of(bytes),
            size: bytes.len() as u64,
            annotations: BTreeMap::new(),
        }
    }

    /// The same descriptor carrying a file-name annotation.
    pub fn named(mut self, name: &str) -> Self {
        self.annotations.insert(NAME_ANNOTATION.to_string(), name.to_string());
        self
    }

    /// The file-name annotation, if present.
    pub fn name(&self) -> Option<&str> {
        self.annotations.get(NAME_ANNOTATION).map(String::as_str)
    }

    /// Serialize to the wire/disk document.
    pub fn to_json(&self) -> Json {
        let mut fields = vec![
            ("mediaType", Json::Str(self.media_type.clone())),
            ("digest", Json::Str(self.digest.clone())),
            ("size", Json::Num(self.size as f64)),
        ];
        if !self.annotations.is_empty() {
            fields.push(("annotations", annotations_json(&self.annotations)));
        }
        obj(fields)
    }

    /// Strict parse: unknown fields, malformed digests, and oversized
    /// strings are errors, not warnings.
    pub fn from_json(doc: &Json) -> Result<Self> {
        strict_keys(doc, "descriptor", &["mediaType", "digest", "size", "annotations"])?;
        let media_type = doc.field("mediaType")?.as_str()?.to_string();
        check_media_type(&media_type, "descriptor")?;
        let digest = doc.field("digest")?.as_str()?.to_string();
        if !is_valid_digest(&digest) {
            return Err(Error::Artifact(format!(
                "descriptor '{media_type}': malformed digest"
            )));
        }
        let size = doc.field("size")?.as_u64()?;
        let annotations = match doc.field("annotations") {
            Ok(v) => parse_annotations(v)?,
            Err(_) => BTreeMap::new(),
        };
        Ok(Self { media_type, digest, size, annotations })
    }
}

/// The artifact manifest: one config descriptor plus ordered layers.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Manifest {
    /// Manifest media type (always [`MANIFEST_MEDIA_TYPE`] today;
    /// carried explicitly so readers can refuse documents they do not
    /// speak, the way `trow` validates incoming manifest types).
    pub media_type: String,
    /// The artifact's configuration blob (farm manifest or job spec).
    pub config: Descriptor,
    /// Content layers in materialization order (snapshots, reports).
    pub layers: Vec<Descriptor>,
    /// Manifest-level annotations (job id, unit index, ...).
    pub annotations: BTreeMap<String, String>,
}

impl Manifest {
    /// A manifest over `config` and `layers` with no annotations.
    pub fn new(config: Descriptor, layers: Vec<Descriptor>) -> Self {
        Self {
            media_type: MANIFEST_MEDIA_TYPE.to_string(),
            config,
            layers,
            annotations: BTreeMap::new(),
        }
    }

    /// Serialize to the wire/disk document.
    pub fn to_json(&self) -> Json {
        let mut fields = vec![
            ("schemaVersion", Json::Num(SCHEMA_VERSION as f64)),
            ("mediaType", Json::Str(self.media_type.clone())),
            ("config", self.config.to_json()),
            ("layers", Json::Arr(self.layers.iter().map(Descriptor::to_json).collect())),
        ];
        if !self.annotations.is_empty() {
            fields.push(("annotations", annotations_json(&self.annotations)));
        }
        obj(fields)
    }

    /// Strict parse (see [`Descriptor::from_json`]).
    pub fn from_json(doc: &Json) -> Result<Self> {
        strict_keys(
            doc,
            "manifest",
            &["schemaVersion", "mediaType", "config", "layers", "annotations"],
        )?;
        let version = doc.field("schemaVersion")?.as_usize()?;
        if version != SCHEMA_VERSION {
            return Err(Error::Artifact(format!(
                "unsupported manifest schemaVersion {version} (this build reads {SCHEMA_VERSION})"
            )));
        }
        let media_type = doc.field("mediaType")?.as_str()?.to_string();
        if media_type != MANIFEST_MEDIA_TYPE {
            return Err(Error::Artifact(format!(
                "unsupported manifest mediaType '{media_type}'"
            )));
        }
        let config = Descriptor::from_json(doc.field("config")?)?;
        let raw_layers = doc.field("layers")?.as_arr()?;
        if raw_layers.len() > MAX_LAYERS {
            return Err(Error::Artifact(format!(
                "manifest claims {} layers (cap {MAX_LAYERS})",
                raw_layers.len()
            )));
        }
        let layers = raw_layers.iter().map(Descriptor::from_json).collect::<Result<Vec<_>>>()?;
        let annotations = match doc.field("annotations") {
            Ok(v) => parse_annotations(v)?,
            Err(_) => BTreeMap::new(),
        };
        Ok(Self { media_type, config, layers, annotations })
    }

    /// The canonical byte form: compact JSON with `BTreeMap`-sorted
    /// keys. These are the bytes a manifest digest addresses, so the
    /// same manifest always has the same address.
    pub fn canonical_bytes(&self) -> Vec<u8> {
        self.to_json().to_string_compact().into_bytes()
    }

    /// This manifest's own registry address.
    pub fn digest(&self) -> String {
        digest_of(&self.canonical_bytes())
    }

    /// Every blob digest this manifest references (config first, then
    /// layers in order) — the GC mark set contribution of one manifest.
    pub fn referenced_blobs(&self) -> Vec<&str> {
        let mut out = Vec::with_capacity(1 + self.layers.len());
        out.push(self.config.digest.as_str());
        out.extend(self.layers.iter().map(|l| l.digest.as_str()));
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Manifest {
        let config = Descriptor::for_bytes(FARM_CONFIG_MEDIA_TYPE, b"{\"farm\":1}");
        let layers = vec![
            Descriptor::for_bytes(SNAPSHOT_MEDIA_TYPE, b"snap-a").named("replica-00000.snap"),
            Descriptor::for_bytes(SNAPSHOT_MEDIA_TYPE, b"snap-b").named("replica-00001.snap"),
        ];
        let mut m = Manifest::new(config, layers);
        m.annotations.insert("org.ising.unit".to_string(), "3".to_string());
        m
    }

    #[test]
    fn roundtrip_is_exact_and_address_is_stable() {
        let m = sample();
        let back = Manifest::from_json(&m.to_json()).unwrap();
        assert_eq!(back, m);
        assert_eq!(back.digest(), m.digest());
        assert!(is_valid_digest(&m.digest()));
        // The canonical bytes parse back to the same document.
        let text = String::from_utf8(m.canonical_bytes()).unwrap();
        let again = Manifest::from_json(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(again, m);
        assert_eq!(again.referenced_blobs().len(), 3);
        assert_eq!(m.layers[0].name(), Some("replica-00000.snap"));
    }

    #[test]
    fn unknown_fields_and_versions_are_rejected() {
        let m = sample();
        let mut doc = m.to_json();
        if let Json::Obj(fields) = &mut doc {
            fields.insert("extra".to_string(), Json::Num(1.0));
        }
        assert!(Manifest::from_json(&doc).is_err());

        let mut doc = m.to_json();
        if let Json::Obj(fields) = &mut doc {
            fields.insert("schemaVersion".to_string(), Json::Num(2.0));
        }
        assert!(Manifest::from_json(&doc).is_err());

        let mut doc = m.to_json();
        if let Json::Obj(fields) = &mut doc {
            fields.insert("mediaType".to_string(), Json::Str("text/plain".to_string()));
        }
        assert!(Manifest::from_json(&doc).is_err());

        // Descriptor-level strictness: unknown field, bad digest.
        let mut doc = m.config.to_json();
        if let Json::Obj(fields) = &mut doc {
            fields.insert("urls".to_string(), Json::Arr(vec![]));
        }
        assert!(Descriptor::from_json(&doc).is_err());
        let mut doc = m.config.to_json();
        if let Json::Obj(fields) = &mut doc {
            fields.insert("digest".to_string(), Json::Str("sha256:nope".to_string()));
        }
        assert!(Descriptor::from_json(&doc).is_err());
    }

    #[test]
    fn caps_bound_hostile_documents() {
        let mut m = sample();
        let layer = m.layers[0].clone();
        m.layers = vec![layer; MAX_LAYERS + 1];
        assert!(Manifest::from_json(&m.to_json()).is_err());

        let mut m = sample();
        m.annotations.insert("k".to_string(), "v".repeat(MAX_STRING + 1));
        assert!(Manifest::from_json(&m.to_json()).is_err());
    }
}
