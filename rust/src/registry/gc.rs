//! Garbage-collection accounting for the artifact store.
//!
//! The sweep itself lives in [`Store::gc`](super::store::Store::gc) —
//! mark from every tag plus the caller's live roots, follow manifests
//! to the blobs they reference, sweep the rest — because it must run
//! under the store's namespace lock. This module holds the report the
//! sweep returns, shared by the `ising artifacts gc` CLI, the tests,
//! and the CI smoke that checks `--dry-run` output.

use crate::util::json::{obj, Json};

/// What one mark/sweep pass found (and, unless `dry_run`, did).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct GcReport {
    /// Blobs reachable from a tag or live root (kept).
    pub kept: usize,
    /// Unreferenced blobs swept — or merely counted under `dry_run`.
    pub swept: usize,
    /// Bytes those swept blobs occupied.
    pub reclaimed_bytes: u64,
    /// True if nothing was deleted.
    pub dry_run: bool,
}

impl GcReport {
    /// JSON form (CLI `--json`-ish consumers and tests).
    pub fn to_json(&self) -> Json {
        obj(vec![
            ("kept", Json::Num(self.kept as f64)),
            ("swept", Json::Num(self.swept as f64)),
            ("reclaimed_bytes", Json::Num(self.reclaimed_bytes as f64)),
            ("dry_run", Json::Bool(self.dry_run)),
        ])
    }

    /// One human line for the CLI (stable: the CI smoke greps it).
    pub fn render(&self) -> String {
        let verb = if self.dry_run { "would sweep" } else { "swept" };
        format!(
            "gc: kept {} blob(s), {verb} {} blob(s) ({} bytes)",
            self.kept, self.swept, self.reclaimed_bytes
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn report_renders_and_serializes() {
        let r = GcReport { kept: 3, swept: 2, reclaimed_bytes: 640, dry_run: true };
        assert_eq!(r.render(), "gc: kept 3 blob(s), would sweep 2 blob(s) (640 bytes)");
        let doc = r.to_json();
        assert_eq!(doc.field("swept").unwrap().as_usize().unwrap(), 2);
        assert!(doc.field("dry_run").unwrap().as_bool().unwrap());
        let wet = GcReport { dry_run: false, ..r };
        assert!(wet.render().starts_with("gc: kept 3 blob(s), swept 2"));
    }
}
