//! Content-addressed artifact registry — the storage substrate under
//! the result cache, the distributed farm, and `ising artifacts`.
//!
//! The paper's multi-GPU scaling story (§4) depends on cheaply moving
//! lattice state between workers. This layer gives those bytes a real
//! storage model, shaped like an OCI registry (see the `oci-spec` /
//! `ocitool` manifest shapes): immutable blobs addressed by their own
//! SHA-256, small JSON **manifests** describing an artifact as a config
//! descriptor plus content layers, and mutable **tags** naming
//! manifests. A farm checkpoint becomes a layered artifact — the
//! `farm.json` manifest as config, one blob per replica/unit snapshot —
//! so jobs sharing a run prefix dedup their common snapshot blobs, a
//! checkpoint can be pushed to / pulled from another node over
//! `/v2/artifacts/...` and verified end-to-end by digest, and
//! refcounted GC ([`Store::gc`]) reclaims whatever no tag or live job
//! reaches.
//!
//! ```text
//! <store>/blobs/sha256/<digest>   immutable bytes (snapshots, specs,
//!                                 reports, manifests)
//! <store>/refs/<name>             tag -> manifest digest
//! ```
//!
//! Module map: [`digest`] (in-tree streaming SHA-256 + digest syntax),
//! [`manifest`] (strict descriptor/manifest documents), [`store`] (the
//! on-disk store + GC), [`gc`] (sweep reports). The helpers below pack
//! a farm checkpoint directory into an artifact and materialize one
//! back — the unit of `ising artifacts push/pull`.

pub mod digest;
pub mod gc;
pub mod manifest;
pub mod store;

pub use digest::{digest_of, is_valid_digest, sha256_hex, Sha256};
pub use gc::GcReport;
pub use manifest::{Descriptor, Manifest};
pub use store::{is_valid_tag, Store, StoreStats};

use crate::coordinator::checkpoint::MANIFEST_FILE;
use crate::error::{Error, Result};
use crate::util::snapshot::atomic_write;
use std::path::Path;

/// Is `name` safe to create inside a checkpoint directory when
/// materializing a pulled artifact? One path segment, conservative
/// charset — a hostile layer annotation cannot escape the directory.
pub fn is_safe_file_name(name: &str) -> bool {
    !name.is_empty()
        && name.len() <= 128
        && name != "."
        && name != ".."
        && name
            .bytes()
            .all(|b| matches!(b, b'a'..=b'z' | b'0'..=b'9' | b'-' | b'_' | b'.'))
}

/// Package a farm checkpoint directory as a layered artifact: the
/// `farm.json` manifest becomes the config layer, every
/// `replica-*.snap` a snapshot layer (streamed into the store, named by
/// a descriptor annotation), and `tag` points at the result. Returns
/// the artifact manifest's digest.
pub fn pack_checkpoint(store: &Store, ckpt_dir: &Path, tag: &str) -> Result<String> {
    let farm_path = ckpt_dir.join(MANIFEST_FILE);
    let farm_bytes = std::fs::read(&farm_path).map_err(|e| {
        Error::Artifact(format!(
            "no farm manifest at '{}': {e} (is this a checkpoint dir?)",
            farm_path.display()
        ))
    })?;
    store.put_blob(&farm_bytes)?;
    let config = Descriptor::for_bytes(manifest::FARM_CONFIG_MEDIA_TYPE, &farm_bytes)
        .named(MANIFEST_FILE);

    let mut layers = Vec::new();
    for path in crate::coordinator::checkpoint::snapshot_files(ckpt_dir)? {
        let Some(name) = path.file_name().and_then(|n| n.to_str()) else { continue };
        let name = name.to_string();
        let (digest, size) = store.ingest_file(&path)?;
        layers.push(Descriptor {
            media_type: manifest::SNAPSHOT_MEDIA_TYPE.to_string(),
            digest,
            size,
            annotations: std::collections::BTreeMap::new(),
        }
        .named(&name));
    }
    let artifact = Manifest::new(config, layers);
    let digest = store.put_manifest(&artifact)?;
    store.tag(tag, &digest)?;
    Ok(digest)
}

/// Materialize an artifact back into a checkpoint directory: the config
/// layer becomes `farm.json`, every named snapshot layer its file. All
/// bytes are digest-verified on the way out of the store, and layer
/// names are validated before any file is created. Returns the parsed
/// manifest.
pub fn unpack_checkpoint(store: &Store, reference: &str, dest: &Path) -> Result<Manifest> {
    let artifact = store.get_manifest(reference)?;
    if artifact.config.media_type != manifest::FARM_CONFIG_MEDIA_TYPE {
        return Err(Error::Artifact(format!(
            "artifact '{reference}' is not a farm checkpoint (config is '{}')",
            artifact.config.media_type
        )));
    }
    std::fs::create_dir_all(dest)?;
    let farm_bytes = store.get_blob(&artifact.config.digest)?;
    atomic_write(&dest.join(MANIFEST_FILE), &farm_bytes)?;
    for layer in &artifact.layers {
        let Some(name) = layer.name() else {
            return Err(Error::Artifact(format!(
                "layer {} carries no file name annotation",
                layer.digest
            )));
        };
        if !is_safe_file_name(name) || name == MANIFEST_FILE {
            return Err(Error::Artifact(format!("unsafe layer file name '{name}'")));
        }
        let bytes = store.get_blob(&layer.digest)?;
        if bytes.len() as u64 != layer.size {
            return Err(Error::Artifact(format!(
                "layer {name}: stored {} bytes, descriptor says {}",
                bytes.len(),
                layer.size
            )));
        }
        atomic_write(&dest.join(name), &bytes)?;
    }
    Ok(artifact)
}

/// Package one fleet unit's leased-checkpoint state: the unit's job
/// spec as config, its snapshot payload as the single layer. This is
/// the manifest the coordinator stores per unit; workers pull the
/// snapshot blob by digest instead of receiving it inline.
pub fn pack_unit(store: &Store, spec_json: &str, snapshot: &[u8], unit: usize) -> Result<String> {
    store.put_blob(spec_json.as_bytes())?;
    store.put_blob(snapshot)?;
    let config = Descriptor::for_bytes(manifest::SPEC_MEDIA_TYPE, spec_json.as_bytes());
    let layer = Descriptor::for_bytes(manifest::SNAPSHOT_MEDIA_TYPE, snapshot)
        .named("replica-00000.snap");
    let mut artifact = Manifest::new(config, vec![layer]);
    artifact
        .annotations
        .insert("org.ising.unit".to_string(), unit.to_string());
    store.put_manifest(&artifact)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::path::PathBuf;

    fn temp_dir(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!(
            "ising-registry-mod-{tag}-{}",
            std::process::id()
        ));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    #[test]
    fn safe_file_names() {
        assert!(is_safe_file_name("replica-00003.snap"));
        assert!(is_safe_file_name("farm.json"));
        for bad in ["", ".", "..", "a/b", "A.snap", "sp ace", &"x".repeat(129)] {
            assert!(!is_safe_file_name(bad), "must reject '{bad}'");
        }
    }

    #[test]
    fn pack_unpack_checkpoint_roundtrip() {
        let root = temp_dir("roundtrip");
        let ckpt = root.join("ckpt");
        std::fs::create_dir_all(&ckpt).unwrap();
        std::fs::write(ckpt.join(MANIFEST_FILE), b"{\"version\": 1}").unwrap();
        std::fs::write(ckpt.join("replica-00000.snap"), b"snap zero").unwrap();
        std::fs::write(ckpt.join("replica-00001.snap"), b"snap one").unwrap();
        // Non-snapshot droppings are not packaged.
        std::fs::write(ckpt.join("notes.txt"), b"ignore me").unwrap();

        let store = Store::open(root.join("store")).unwrap();
        let digest = pack_checkpoint(&store, &ckpt, "runs/demo").unwrap();
        assert_eq!(store.resolve("runs/demo").unwrap(), digest);
        let artifact = store.get_manifest("runs/demo").unwrap();
        assert_eq!(artifact.layers.len(), 2);
        assert_eq!(artifact.layers[0].name(), Some("replica-00000.snap"));

        let out = root.join("restored");
        let back = unpack_checkpoint(&store, "runs/demo", &out).unwrap();
        assert_eq!(back, artifact);
        assert_eq!(std::fs::read(out.join(MANIFEST_FILE)).unwrap(), b"{\"version\": 1}");
        assert_eq!(std::fs::read(out.join("replica-00001.snap")).unwrap(), b"snap one");
        assert!(!out.join("notes.txt").exists());
        let _ = std::fs::remove_dir_all(&root);
    }

    #[test]
    fn pack_requires_a_checkpoint_dir_and_unpack_validates_names() {
        let root = temp_dir("invalid");
        let store = Store::open(root.join("store")).unwrap();
        assert!(pack_checkpoint(&store, &root.join("empty"), "t").is_err());

        // A manifest with a hostile layer name is refused at unpack.
        let spec = b"{}";
        let snap = b"payload";
        store.put_blob(spec).unwrap();
        store.put_blob(snap).unwrap();
        let config = Descriptor::for_bytes(manifest::FARM_CONFIG_MEDIA_TYPE, spec);
        let evil =
            Descriptor::for_bytes(manifest::SNAPSHOT_MEDIA_TYPE, snap).named("../escape.snap");
        let m = Manifest::new(config, vec![evil]);
        let d = store.put_manifest(&m).unwrap();
        assert!(unpack_checkpoint(&store, &d, &root.join("out")).is_err());
        assert!(!root.join("escape.snap").exists());
        let _ = std::fs::remove_dir_all(&root);
    }

    #[test]
    fn unit_artifacts_share_spec_blobs() {
        let root = temp_dir("unit");
        let store = Store::open(root.join("store")).unwrap();
        let d0 = pack_unit(&store, "{\"spec\": 1}", b"snapshot-0", 0).unwrap();
        let d1 = pack_unit(&store, "{\"spec\": 1}", b"snapshot-1", 1).unwrap();
        assert_ne!(d0, d1);
        let m0 = store.get_manifest(&d0).unwrap();
        let m1 = store.get_manifest(&d1).unwrap();
        // The shared spec blob is stored once.
        assert_eq!(m0.config.digest, m1.config.digest);
        // 1 spec + 2 snapshots + 2 manifests.
        assert_eq!(store.stats().unwrap().blobs, 5);
        assert_eq!(m0.annotations.get("org.ising.unit").map(String::as_str), Some("0"));
        let _ = std::fs::remove_dir_all(&root);
    }
}
