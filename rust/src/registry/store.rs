//! The content-addressed store: blobs, manifests, and tags on disk.
//!
//! Layout (all paths under one store root):
//!
//! ```text
//! <root>/blobs/sha256/<64 hex>   blob bytes, named by their own digest
//! <root>/refs/<name>             tag file: one manifest digest + '\n'
//! ```
//!
//! Blobs are immutable once published: ingest streams the bytes through
//! SHA-256, writes a uniquely-named temp file, and renames it into
//! place, so a crash mid-ingest leaves garbage temp files (reclaimed by
//! GC) but never a half-written blob under a valid address. Reads
//! rehash the file and refuse to return bytes whose digest does not
//! match the address — disk corruption surfaces as an error, not as
//! wrong physics. Manifests are ordinary blobs holding their canonical
//! JSON, so one namespace and one GC walk covers everything; tags are
//! the only mutable state.
//!
//! Concurrency: one mutex (`refs`, see the `ising-lint` lock table)
//! serializes namespace mutation — blob publication, tag writes, and
//! the GC mark/sweep — so a sweep can never race a rename and collect a
//! blob that just became referenced. Reads take no lock: blob files are
//! immutable and tag files are replaced atomically.

use crate::error::{Error, Result};
use crate::obs::Obs;
use crate::util::json::Json;
use crate::util::snapshot::atomic_write;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use super::digest::{digest_of, is_valid_digest, to_hex, Sha256, ALGORITHM};
use super::gc::GcReport;
use super::manifest::{Manifest, MANIFEST_MEDIA_TYPE};

/// Blob subdirectory under the store root.
pub const BLOBS_SUBDIR: &str = "blobs";
/// Tag subdirectory under the store root.
pub const REFS_SUBDIR: &str = "refs";
/// Longest accepted tag name.
pub const MAX_TAG: usize = 128;
/// Streaming-ingest chunk size (file ingest hashes and copies in these
/// units instead of buffering whole artifacts).
const INGEST_CHUNK: usize = 64 * 1024;

/// Per-process temp-name disambiguator for concurrent ingests of the
/// same content (each writer gets its own temp file; the rename is what
/// races, harmlessly, under the namespace lock).
static INGEST_SEQ: AtomicU64 = AtomicU64::new(0);

/// Is `name` a well-formed tag? Lowercase path-ish names only
/// (`jobs/<id>/result`, `units/unit-00003`), every segment non-empty
/// and free of path tricks — enforced before any name coming off the
/// wire or the CLI touches the filesystem.
pub fn is_valid_tag(name: &str) -> bool {
    !name.is_empty()
        && name.len() <= MAX_TAG
        && name.split('/').all(|seg| {
            !seg.is_empty()
                && seg != "."
                && seg != ".."
                && seg
                    .bytes()
                    .all(|b| matches!(b, b'a'..=b'z' | b'0'..=b'9' | b'-' | b'_' | b'.'))
        })
}

/// Aggregate store accounting for the scrape-time gauges and `gc`.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct StoreStats {
    /// Number of stored blobs (manifests included).
    pub blobs: usize,
    /// Total stored bytes across all blobs.
    pub bytes: u64,
}

/// A content-addressed artifact store rooted at one directory.
#[derive(Debug)]
pub struct Store {
    root: PathBuf,
    /// Namespace mutation lock: blob publication, tag writes, GC.
    refs: Mutex<()>,
    /// Metrics/trace sink; `None` for plain CLI use.
    obs: Option<Arc<Obs>>,
}

impl Store {
    /// Open (creating the layout if missing), without observability.
    pub fn open(root: PathBuf) -> Result<Self> {
        Self::build(root, None)
    }

    /// Open with a metrics/trace sink (the serving layers).
    pub fn with_obs(root: PathBuf, obs: Arc<Obs>) -> Result<Self> {
        Self::build(root, Some(obs))
    }

    fn build(root: PathBuf, obs: Option<Arc<Obs>>) -> Result<Self> {
        std::fs::create_dir_all(root.join(BLOBS_SUBDIR).join(ALGORITHM))?;
        std::fs::create_dir_all(root.join(REFS_SUBDIR))?;
        Ok(Self { root, refs: Mutex::new(()), obs })
    }

    /// Store root.
    pub fn root(&self) -> &Path {
        &self.root
    }

    /// On-disk path of a (validated) digest.
    pub fn blob_path(&self, digest: &str) -> Result<PathBuf> {
        let hex = super::digest::digest_hex(digest)?;
        Ok(self.root.join(BLOBS_SUBDIR).join(ALGORITHM).join(hex))
    }

    fn count_ingest(&self, outcome: &str) {
        if let Some(obs) = &self.obs {
            obs.metrics.counter(
                "registry_blob_ingests_total",
                "Blob ingests into the artifact store by outcome.",
                &[("outcome", outcome)],
                1.0,
            );
        }
    }

    fn count_read(&self, outcome: &str) {
        if let Some(obs) = &self.obs {
            obs.metrics.counter(
                "registry_blob_reads_total",
                "Blob reads from the artifact store by outcome.",
                &[("outcome", outcome)],
                1.0,
            );
        }
    }

    /// Publish `tmp` (already holding the full bytes) at the blob
    /// address, under the namespace lock. Returns `true` if this call
    /// created the blob, `false` on dedup (the temp file is removed).
    fn publish_tmp(&self, tmp: &Path, path: &Path) -> Result<bool> {
        let _guard = self.refs.lock().expect("registry refs lock poisoned");
        if path.is_file() {
            let _ = std::fs::remove_file(tmp);
            return Ok(false);
        }
        std::fs::rename(tmp, path)?;
        Ok(true)
    }

    /// Ingest in-memory bytes; returns the blob's digest. Idempotent:
    /// re-ingesting existing content is a dedup hit, not a rewrite.
    pub fn put_blob(&self, bytes: &[u8]) -> Result<String> {
        let digest = digest_of(bytes);
        let path = self.blob_path(&digest)?;
        let tmp = self.tmp_path(&path);
        std::fs::write(&tmp, bytes)?;
        let created = self.publish_tmp(&tmp, &path)?;
        self.count_ingest(if created { "new" } else { "dedup" });
        Ok(digest)
    }

    /// Ingest bytes that arrived with a claimed address (a blob PUT off
    /// the wire): the claim is verified against the actual content and
    /// a mismatch is rejected before anything is stored.
    pub fn put_blob_verified(&self, bytes: &[u8], claimed: &str) -> Result<String> {
        super::digest::digest_hex(claimed)?;
        let actual = digest_of(bytes);
        if actual != claimed {
            self.count_ingest("rejected");
            return Err(Error::Artifact(format!(
                "digest mismatch: body hashes to {actual}, request claimed {claimed}"
            )));
        }
        self.put_blob(bytes)
    }

    /// Ingest a file without buffering it: stream it through SHA-256
    /// while copying into a temp file, then publish under the computed
    /// address. Returns `(digest, size)`.
    pub fn ingest_file(&self, src: &Path) -> Result<(String, u64)> {
        use std::io::{Read, Write};
        let mut reader = std::fs::File::open(src)?;
        let staging = self.root.join(BLOBS_SUBDIR).join(ALGORITHM).join(format!(
            "ingest-{}-{}.tmp",
            std::process::id(),
            INGEST_SEQ.fetch_add(1, Ordering::Relaxed)
        ));
        let mut writer = std::fs::File::create(&staging)?;
        let mut hasher = Sha256::new();
        let mut size = 0u64;
        let mut buf = vec![0u8; INGEST_CHUNK];
        loop {
            let n = reader.read(&mut buf)?;
            if n == 0 {
                break;
            }
            let (chunk, _) = buf.split_at(n);
            hasher.update(chunk);
            writer.write_all(chunk)?;
            size += n as u64;
        }
        writer.sync_all()?;
        drop(writer);
        let digest = format!("{ALGORITHM}:{}", to_hex(&hasher.finalize()));
        let path = self.blob_path(&digest)?;
        let created = self.publish_tmp(&staging, &path)?;
        self.count_ingest(if created { "new" } else { "dedup" });
        Ok((digest, size))
    }

    /// Is this digest stored?
    pub fn has_blob(&self, digest: &str) -> bool {
        self.blob_path(digest).map(|p| p.is_file()).unwrap_or(false)
    }

    /// Stored size of a blob, if present.
    pub fn blob_size(&self, digest: &str) -> Option<u64> {
        let path = self.blob_path(digest).ok()?;
        std::fs::metadata(path).ok().map(|m| m.len())
    }

    /// Read a blob and verify it still hashes to its address. A missing
    /// blob is a miss; a corrupt blob is a loud error, never bytes.
    pub fn get_blob(&self, digest: &str) -> Result<Vec<u8>> {
        let path = self.blob_path(digest)?;
        let bytes = match std::fs::read(&path) {
            Ok(b) => b,
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => {
                self.count_read("miss");
                return Err(Error::Artifact(format!("no blob {digest} in store")));
            }
            Err(e) => return Err(e.into()),
        };
        let actual = digest_of(&bytes);
        if actual != digest {
            self.count_read("corrupt");
            return Err(Error::Artifact(format!(
                "blob {digest} is corrupt on disk (content hashes to {actual})"
            )));
        }
        self.count_read("hit");
        Ok(bytes)
    }

    /// Store a manifest (as a blob of its canonical bytes) and return
    /// its digest. Every blob it references must already be present —
    /// the same "layers before manifest" ordering real registries
    /// enforce, so a stored manifest is always materializable.
    pub fn put_manifest(&self, manifest: &Manifest) -> Result<String> {
        for digest in manifest.referenced_blobs() {
            if !self.has_blob(digest) {
                return Err(Error::Artifact(format!(
                    "manifest references missing blob {digest}; push blobs before the manifest"
                )));
            }
        }
        self.put_blob(&manifest.canonical_bytes())
    }

    /// Load a manifest by tag or digest, verifying blob integrity and
    /// strict-parsing the document.
    pub fn get_manifest(&self, reference: &str) -> Result<Manifest> {
        let digest = self.resolve(reference)?;
        let bytes = self.get_blob(&digest)?;
        let text = String::from_utf8(bytes)
            .map_err(|_| Error::Artifact(format!("manifest {digest} is not UTF-8")))?;
        Manifest::from_json(&Json::parse(&text)?)
    }

    /// On-disk path of a (validated) tag.
    fn tag_path(&self, name: &str) -> Result<PathBuf> {
        if !is_valid_tag(name) {
            let shown: String = name.chars().take(80).collect();
            return Err(Error::Artifact(format!("malformed tag name '{shown}'")));
        }
        Ok(self.root.join(REFS_SUBDIR).join(name))
    }

    /// Point tag `name` at a stored manifest digest (atomic replace).
    pub fn tag(&self, name: &str, manifest_digest: &str) -> Result<()> {
        let path = self.tag_path(name)?;
        super::digest::digest_hex(manifest_digest)?;
        if !self.has_blob(manifest_digest) {
            return Err(Error::Artifact(format!(
                "cannot tag '{name}': no manifest blob {manifest_digest} in store"
            )));
        }
        if let Some(parent) = path.parent() {
            std::fs::create_dir_all(parent)?;
        }
        let _guard = self.refs.lock().expect("registry refs lock poisoned");
        atomic_write(&path, format!("{manifest_digest}\n").as_bytes())
    }

    /// Remove a tag; `true` if it existed. The manifest and blobs stay
    /// until a GC sweep finds them unreferenced.
    pub fn delete_tag(&self, name: &str) -> Result<bool> {
        let path = self.tag_path(name)?;
        let _guard = self.refs.lock().expect("registry refs lock poisoned");
        match std::fs::remove_file(path) {
            Ok(()) => Ok(true),
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => Ok(false),
            Err(e) => Err(e.into()),
        }
    }

    /// Resolve a reference: a digest resolves to itself, a tag to the
    /// digest its file records.
    pub fn resolve(&self, reference: &str) -> Result<String> {
        if is_valid_digest(reference) {
            return Ok(reference.to_string());
        }
        let path = self.tag_path(reference)?;
        let text = match std::fs::read_to_string(path) {
            Ok(t) => t,
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => {
                return Err(Error::Artifact(format!("no tag '{reference}' in store")));
            }
            Err(e) => return Err(e.into()),
        };
        let digest = text.trim();
        if !is_valid_digest(digest) {
            return Err(Error::Artifact(format!("tag '{reference}' holds a malformed digest")));
        }
        Ok(digest.to_string())
    }

    /// All tags as `(name, manifest digest)`, sorted by name.
    pub fn tags(&self) -> Result<Vec<(String, String)>> {
        let refs_root = self.root.join(REFS_SUBDIR);
        let mut out = Vec::new();
        let mut stack = vec![refs_root.clone()];
        while let Some(dir) = stack.pop() {
            let entries = match std::fs::read_dir(&dir) {
                Ok(e) => e,
                Err(_) => continue,
            };
            for entry in entries.flatten() {
                let path = entry.path();
                if path.is_dir() {
                    stack.push(path);
                    continue;
                }
                let Ok(rel) = path.strip_prefix(&refs_root) else { continue };
                let Some(name) = rel.to_str() else { continue };
                let name = name.replace('\\', "/");
                if !is_valid_tag(&name) {
                    continue;
                }
                if let Ok(digest) = self.resolve(&name) {
                    out.push((name, digest));
                }
            }
        }
        out.sort();
        Ok(out)
    }

    /// All stored blob digests, sorted.
    pub fn blobs(&self) -> Result<Vec<String>> {
        let mut out = Vec::new();
        let dir = self.root.join(BLOBS_SUBDIR).join(ALGORITHM);
        if let Ok(entries) = std::fs::read_dir(dir) {
            for entry in entries.flatten() {
                let name = entry.file_name();
                let Some(hex) = name.to_str() else { continue };
                let digest = format!("{ALGORITHM}:{hex}");
                if is_valid_digest(&digest) && entry.path().is_file() {
                    out.push(digest);
                }
            }
        }
        out.sort();
        Ok(out)
    }

    /// Blob count and total bytes (the scrape-time store gauges).
    pub fn stats(&self) -> Result<StoreStats> {
        let mut stats = StoreStats::default();
        for digest in self.blobs()? {
            stats.blobs += 1;
            stats.bytes += self.blob_size(&digest).unwrap_or(0);
        }
        Ok(stats)
    }

    /// Refcounted mark/sweep GC. Roots are every tag plus the caller's
    /// `live_roots` (tags or digests — how the serving layers pin
    /// in-flight jobs that have no tag yet); marking follows manifests
    /// to the blobs they reference. Unmarked blobs (and stale ingest
    /// temp files) are swept — or only counted when `dry_run`. The
    /// whole walk holds the namespace lock, so a concurrent tag or
    /// publish either lands before the mark or after the sweep.
    pub fn gc(&self, live_roots: &[String], dry_run: bool) -> Result<GcReport> {
        let start = crate::obs::clock::now();
        let guard = self.refs.lock().expect("registry refs lock poisoned");
        let mut marked: std::collections::BTreeSet<String> = std::collections::BTreeSet::new();
        let mut roots: Vec<String> = Vec::new();
        for (_, digest) in self.tags_unlocked()? {
            roots.push(digest);
        }
        for root in live_roots {
            if is_valid_digest(root) {
                roots.push(root.clone());
            } else if let Ok(digest) = self.resolve_unlocked(root) {
                roots.push(digest);
            }
            // An unresolvable live root pins nothing — the job it
            // described has no artifact yet.
        }
        for digest in roots {
            if !marked.insert(digest.clone()) {
                continue;
            }
            // Follow manifests one level down to the blobs they pin.
            if let Ok(manifest) = self.read_manifest_unlocked(&digest) {
                for blob in manifest.referenced_blobs() {
                    marked.insert(blob.to_string());
                }
            }
        }
        let mut report = GcReport { dry_run, ..GcReport::default() };
        for digest in self.blobs()? {
            if marked.contains(&digest) {
                report.kept += 1;
                continue;
            }
            let size = self.blob_size(&digest).unwrap_or(0);
            if !dry_run {
                std::fs::remove_file(self.blob_path(&digest)?)?;
            }
            report.swept += 1;
            report.reclaimed_bytes += size;
        }
        // Stale ingest temp files (a crashed writer) are garbage too.
        let blob_dir = self.root.join(BLOBS_SUBDIR).join(ALGORITHM);
        if let Ok(entries) = std::fs::read_dir(&blob_dir) {
            for entry in entries.flatten() {
                let name = entry.file_name();
                let Some(name) = name.to_str() else { continue };
                if name.ends_with(".tmp") && !dry_run {
                    let _ = std::fs::remove_file(entry.path());
                }
            }
        }
        drop(guard);
        if let Some(obs) = &self.obs {
            obs.metrics.observe(
                "registry_gc_duration_seconds",
                "Wall time of registry GC mark/sweep passes.",
                &[],
                start.elapsed().as_secs_f64(),
            );
            obs.metrics.counter(
                "registry_gc_swept_blobs_total",
                "Blobs reclaimed by registry GC (dry runs excluded).",
                &[],
                if dry_run { 0.0 } else { report.swept as f64 },
            );
        }
        Ok(report)
    }

    /// Tag enumeration under the GC guard. `tags()` never locks (tag
    /// files are replaced atomically), so this alias only documents
    /// that the call is intentional, not a re-entrancy hazard.
    fn tags_unlocked(&self) -> Result<Vec<(String, String)>> {
        self.tags()
    }

    /// Reference resolution under the GC guard (see `tags_unlocked`).
    fn resolve_unlocked(&self, reference: &str) -> Result<String> {
        self.resolve(reference)
    }

    /// Parse a stored blob as a manifest if it is one (lock-free read).
    fn read_manifest_unlocked(&self, digest: &str) -> Result<Manifest> {
        let path = self.blob_path(digest)?;
        let bytes = std::fs::read(path)?;
        if digest_of(&bytes) != digest {
            return Err(Error::Artifact(format!("blob {digest} is corrupt on disk")));
        }
        let text = String::from_utf8(bytes)
            .map_err(|_| Error::Artifact("not a manifest".to_string()))?;
        let manifest = Manifest::from_json(&Json::parse(&text)?)?;
        if manifest.media_type != MANIFEST_MEDIA_TYPE {
            return Err(Error::Artifact("not a manifest".to_string()));
        }
        Ok(manifest)
    }

    fn tmp_path(&self, path: &Path) -> PathBuf {
        let mut name = path.as_os_str().to_os_string();
        name.push(format!(
            ".{}-{}.tmp",
            std::process::id(),
            INGEST_SEQ.fetch_add(1, Ordering::Relaxed)
        ));
        PathBuf::from(name)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::registry::manifest::{Descriptor, SNAPSHOT_MEDIA_TYPE, SPEC_MEDIA_TYPE};

    fn temp_store(tag: &str) -> Store {
        let dir = std::env::temp_dir().join(format!(
            "ising-registry-{tag}-{}",
            std::process::id()
        ));
        let _ = std::fs::remove_dir_all(&dir);
        Store::open(dir).unwrap()
    }

    fn manifest_for(config: &[u8], layers: &[&[u8]]) -> Manifest {
        Manifest::new(
            Descriptor::for_bytes(SPEC_MEDIA_TYPE, config),
            layers
                .iter()
                .map(|l| Descriptor::for_bytes(SNAPSHOT_MEDIA_TYPE, l))
                .collect(),
        )
    }

    #[test]
    fn blob_roundtrip_dedup_and_corruption() {
        let store = temp_store("blob");
        let digest = store.put_blob(b"hello registry").unwrap();
        assert!(store.has_blob(&digest));
        assert_eq!(store.blob_size(&digest), Some(14));
        assert_eq!(store.get_blob(&digest).unwrap(), b"hello registry");
        // Idempotent re-ingest.
        assert_eq!(store.put_blob(b"hello registry").unwrap(), digest);
        assert_eq!(store.blobs().unwrap(), vec![digest.clone()]);
        // A flipped byte on disk is detected on read.
        let path = store.blob_path(&digest).unwrap();
        std::fs::write(&path, b"hello Registry").unwrap();
        assert!(store.get_blob(&digest).is_err());
        // Missing blobs are a miss, not a panic.
        let ghost = digest_of(b"never stored");
        assert!(!store.has_blob(&ghost));
        assert!(store.get_blob(&ghost).is_err());
        let _ = std::fs::remove_dir_all(store.root());
    }

    #[test]
    fn verified_ingest_rejects_wrong_claims() {
        let store = temp_store("verified");
        let claimed = digest_of(b"other bytes");
        assert!(store.put_blob_verified(b"these bytes", &claimed).is_err());
        assert!(!store.has_blob(&claimed));
        let good = digest_of(b"these bytes");
        assert_eq!(store.put_blob_verified(b"these bytes", &good).unwrap(), good);
        assert!(store.put_blob_verified(b"x", "sha256:zz").is_err());
        let _ = std::fs::remove_dir_all(store.root());
    }

    #[test]
    fn file_ingest_streams_and_matches_in_memory_digest() {
        let store = temp_store("ingest");
        let src = store.root().join("payload.bin");
        let data: Vec<u8> = (0u32..200_000).map(|i| (i % 251) as u8).collect();
        std::fs::write(&src, &data).unwrap();
        let (digest, size) = store.ingest_file(&src).unwrap();
        assert_eq!(size, data.len() as u64);
        assert_eq!(digest, digest_of(&data));
        assert_eq!(store.get_blob(&digest).unwrap(), data);
        let _ = std::fs::remove_dir_all(store.root());
    }

    #[test]
    fn manifests_require_their_blobs_and_tags_resolve() {
        let store = temp_store("manifest");
        let m = manifest_for(b"{\"cfg\":1}", &[b"snap"]);
        // Blobs must land first.
        assert!(store.put_manifest(&m).is_err());
        store.put_blob(b"{\"cfg\":1}").unwrap();
        store.put_blob(b"snap").unwrap();
        let digest = store.put_manifest(&m).unwrap();
        assert_eq!(digest, m.digest());
        assert_eq!(store.get_manifest(&digest).unwrap(), m);

        store.tag("jobs/abc/result", &digest).unwrap();
        assert_eq!(store.resolve("jobs/abc/result").unwrap(), digest);
        assert_eq!(store.get_manifest("jobs/abc/result").unwrap(), m);
        assert_eq!(
            store.tags().unwrap(),
            vec![("jobs/abc/result".to_string(), digest.clone())]
        );
        assert!(store.delete_tag("jobs/abc/result").unwrap());
        assert!(!store.delete_tag("jobs/abc/result").unwrap());
        assert!(store.resolve("jobs/abc/result").is_err());
        // Tagging an absent manifest is refused.
        assert!(store.tag("x", &digest_of(b"ghost")).is_err());
        let _ = std::fs::remove_dir_all(store.root());
    }

    #[test]
    fn tag_names_are_validated() {
        assert!(is_valid_tag("jobs/0011aabb/result"));
        assert!(is_valid_tag("units/unit-00003"));
        for bad in [
            "",
            "/lead",
            "trail/",
            "a//b",
            "../escape",
            "a/../b",
            "UPPER",
            "sp ace",
            "way/too/long/aaaaaaaaaaaaaaaaaaaaaaaaaaaaaaaaaaaaaaaaaaaaaaaaaaaaaaaaaaaaaaaaaaaaaaaaaaaaaaaaaaaaaaaaaaaaaaaaaaaaaaaaaaaaaaaaaaaaaaaaaaaaaaaaa",
        ] {
            assert!(!is_valid_tag(bad), "must reject '{bad}'");
        }
        let store = temp_store("tags");
        assert!(store.tag("../escape", "sha256:00").is_err());
        assert!(store.resolve("../escape").is_err());
        let _ = std::fs::remove_dir_all(store.root());
    }

    #[test]
    fn gc_keeps_tagged_and_live_sweeps_the_rest() {
        let store = temp_store("gc");
        // Artifact A: tagged. Artifact B: untagged but live. C: orphan.
        let config = b"{\"cfg\":1}";
        store.put_blob(config).unwrap();
        store.put_blob(b"snap-a").unwrap();
        store.put_blob(b"snap-b").unwrap();
        let orphan = store.put_blob(b"orphan").unwrap();
        let ma = manifest_for(config, &[b"snap-a"]);
        let mb = manifest_for(config, &[b"snap-b"]);
        let da = store.put_manifest(&ma).unwrap();
        let db = store.put_manifest(&mb).unwrap();
        store.tag("keep/a", &da).unwrap();

        // Dry run reports but removes nothing.
        let dry = store.gc(&[db.clone()], true).unwrap();
        assert!(dry.dry_run);
        assert_eq!(dry.swept, 1, "{dry:?}");
        assert!(store.has_blob(&orphan));

        let report = store.gc(&[db.clone()], false).unwrap();
        assert_eq!(report.swept, 1);
        assert!(!store.has_blob(&orphan));
        // Everything reachable from the tag or the live root survives,
        // including the shared config blob.
        for d in [&da, &db] {
            assert!(store.has_blob(d));
        }
        assert_eq!(store.get_manifest(&da).unwrap(), ma);
        assert_eq!(store.get_manifest(&db).unwrap(), mb);
        // Dropping the live root sweeps B's manifest and private layer
        // but keeps the config blob A still references.
        let report = store.gc(&[], false).unwrap();
        assert_eq!(report.swept, 2);
        assert!(store.has_blob(&da));
        assert!(!store.has_blob(&db));
        assert_eq!(store.get_manifest("keep/a").unwrap(), ma);
        let _ = std::fs::remove_dir_all(store.root());
    }

    #[test]
    fn shared_layers_dedup_by_blob_count() {
        let store = temp_store("dedup");
        let config = b"{\"run\":\"prefix\"}";
        let shared = b"common-snapshot";
        store.put_blob(config).unwrap();
        store.put_blob(shared).unwrap();
        store.put_blob(b"only-a").unwrap();
        store.put_blob(b"only-b").unwrap();
        let ma = manifest_for(config, &[shared, b"only-a"]);
        let mb = manifest_for(config, &[shared, b"only-b"]);
        let da = store.put_manifest(&ma).unwrap();
        let db = store.put_manifest(&mb).unwrap();
        store.tag("jobs/a", &da).unwrap();
        store.tag("jobs/b", &db).unwrap();
        // 4 content blobs + 2 manifests — the shared config and shared
        // snapshot exist exactly once.
        assert_eq!(store.stats().unwrap().blobs, 6);
        let _ = std::fs::remove_dir_all(store.root());
    }
}
