//! Streaming SHA-256 (FIPS 180-4) and the `sha256:<hex>` digest syntax —
//! the content-addressing primitive of the artifact registry.
//!
//! Implemented in-tree because the offline image bakes in no crypto
//! crates (the same reason `util::snapshot` carries its own CRC-32).
//! SHA-256 is the registry's *identity* function, not a security
//! boundary per se, but it still gives artifacts a collision-resistant
//! address and an end-to-end integrity check that the per-file CRC of
//! the snapshot container never provided: a blob read back from disk or
//! pulled over HTTP is rehashed and compared against its address before
//! any byte is trusted.
//!
//! Verified against the FIPS 180-4 example vectors ("abc", the
//! two-block message) and cross-checked against Python's `hashlib` in
//! the unit tests; chunking invariance (any split of the input hashes
//! identically) is covered by `tests/properties.rs`.

use crate::error::{Error, Result};

/// The only digest algorithm the registry speaks, as the address prefix.
pub const ALGORITHM: &str = "sha256";

/// SHA-256 round constants (fractional parts of the cube roots of the
/// first 64 primes).
const K: [u32; 64] = [
    0x428a2f98, 0x71374491, 0xb5c0fbcf, 0xe9b5dba5, 0x3956c25b, 0x59f111f1, 0x923f82a4,
    0xab1c5ed5, 0xd807aa98, 0x12835b01, 0x243185be, 0x550c7dc3, 0x72be5d74, 0x80deb1fe,
    0x9bdc06a7, 0xc19bf174, 0xe49b69c1, 0xefbe4786, 0x0fc19dc6, 0x240ca1cc, 0x2de92c6f,
    0x4a7484aa, 0x5cb0a9dc, 0x76f988da, 0x983e5152, 0xa831c66d, 0xb00327c8, 0xbf597fc7,
    0xc6e00bf3, 0xd5a79147, 0x06ca6351, 0x14292967, 0x27b70a85, 0x2e1b2138, 0x4d2c6dfc,
    0x53380d13, 0x650a7354, 0x766a0abb, 0x81c2c92e, 0x92722c85, 0xa2bfe8a1, 0xa81a664b,
    0xc24b8b70, 0xc76c51a3, 0xd192e819, 0xd6990624, 0xf40e3585, 0x106aa070, 0x19a4c116,
    0x1e376c08, 0x2748774c, 0x34b0bcb5, 0x391c0cb3, 0x4ed8aa4a, 0x5b9cca4f, 0x682e6ff3,
    0x748f82ee, 0x78a5636f, 0x84c87814, 0x8cc70208, 0x90befffa, 0xa4506ceb, 0xbef9a3f7,
    0xc67178f2,
];

/// Initial hash state (fractional parts of the square roots of the
/// first 8 primes).
const H0: [u32; 8] = [
    0x6a09e667, 0xbb67ae85, 0x3c6ef372, 0xa54ff53a, 0x510e527f, 0x9b05688c, 0x1f83d9ab,
    0x5be0cd19,
];

fn ch(x: u32, y: u32, z: u32) -> u32 {
    (x & y) ^ (!x & z)
}

fn maj(x: u32, y: u32, z: u32) -> u32 {
    (x & y) ^ (x & z) ^ (y & z)
}

fn bsig0(x: u32) -> u32 {
    x.rotate_right(2) ^ x.rotate_right(13) ^ x.rotate_right(22)
}

fn bsig1(x: u32) -> u32 {
    x.rotate_right(6) ^ x.rotate_right(11) ^ x.rotate_right(25)
}

fn ssig0(x: u32) -> u32 {
    x.rotate_right(7) ^ x.rotate_right(18) ^ (x >> 3)
}

fn ssig1(x: u32) -> u32 {
    x.rotate_right(17) ^ x.rotate_right(19) ^ (x >> 10)
}

/// Incremental SHA-256: feed bytes with [`update`](Sha256::update) in
/// any chunking, read the digest with [`finalize`](Sha256::finalize).
/// Blob ingest streams file contents through one of these instead of
/// buffering the whole artifact.
pub struct Sha256 {
    state: [u32; 8],
    /// Unprocessed input tail (always shorter than one 64-byte block
    /// between calls).
    buf: Vec<u8>,
    /// Total message length in bytes (the padding block encodes it in
    /// bits; SHA-256 caps messages at 2^64 - 1 bits, far beyond any
    /// artifact this store will see).
    total: u64,
}

impl Sha256 {
    /// Fresh hasher.
    pub fn new() -> Self {
        Self { state: H0, buf: Vec::with_capacity(64), total: 0 }
    }

    /// Absorb `bytes`; chunking never changes the digest.
    pub fn update(&mut self, mut bytes: &[u8]) {
        self.total = self.total.wrapping_add(bytes.len() as u64);
        if !self.buf.is_empty() {
            let need = 64 - self.buf.len();
            let take = need.min(bytes.len());
            let (head, rest) = bytes.split_at(take);
            self.buf.extend_from_slice(head);
            bytes = rest;
            if self.buf.len() < 64 {
                return;
            }
            let block = std::mem::take(&mut self.buf);
            self.compress(&block);
            self.buf = block;
            self.buf.clear();
        }
        let mut chunks = bytes.chunks_exact(64);
        for block in chunks.by_ref() {
            self.compress(block);
        }
        self.buf.extend_from_slice(chunks.remainder());
    }

    /// Pad, absorb the length block, and return the 32-byte digest.
    pub fn finalize(mut self) -> [u8; 32] {
        let bit_len = self.total.wrapping_mul(8);
        let mut tail = std::mem::take(&mut self.buf);
        tail.push(0x80);
        while tail.len() % 64 != 56 {
            tail.push(0);
        }
        tail.extend_from_slice(&bit_len.to_be_bytes());
        for block in tail.chunks_exact(64) {
            self.compress(block);
        }
        let mut out = [0u8; 32];
        for (chunk, word) in out.chunks_exact_mut(4).zip(self.state.iter()) {
            chunk.copy_from_slice(&word.to_be_bytes());
        }
        out
    }

    /// One 64-byte block through the compression function.
    fn compress(&mut self, block: &[u8]) {
        // Message-schedule read: every position the expansion loop asks
        // for is already filled (t ranges over 16..64, reads reach back
        // at most 16), so the fallback arm is unreachable — and a logic
        // error here would fail the FIPS vectors, not index out of
        // bounds.
        fn sched(w: &[u32], i: usize) -> u32 {
            w.get(i).copied().unwrap_or(0)
        }
        let mut w: Vec<u32> = Vec::with_capacity(64);
        for chunk in block.chunks_exact(4) {
            w.push(u32::from_be_bytes([chunk[0], chunk[1], chunk[2], chunk[3]]));
        }
        for t in 16..64 {
            let wt = ssig1(sched(&w, t - 2))
                .wrapping_add(sched(&w, t - 7))
                .wrapping_add(ssig0(sched(&w, t - 15)))
                .wrapping_add(sched(&w, t - 16));
            w.push(wt);
        }
        let [mut a, mut b, mut c, mut d, mut e, mut f, mut g, mut h] = self.state;
        for (&wt, &kt) in w.iter().zip(K.iter()) {
            let t1 = h
                .wrapping_add(bsig1(e))
                .wrapping_add(ch(e, f, g))
                .wrapping_add(kt)
                .wrapping_add(wt);
            let t2 = bsig0(a).wrapping_add(maj(a, b, c));
            h = g;
            g = f;
            f = e;
            e = d.wrapping_add(t1);
            d = c;
            c = b;
            b = a;
            a = t1.wrapping_add(t2);
        }
        for (s, v) in self.state.iter_mut().zip([a, b, c, d, e, f, g, h]) {
            *s = s.wrapping_add(v);
        }
    }
}

impl Default for Sha256 {
    fn default() -> Self {
        Self::new()
    }
}

/// Lowercase-hex SHA-256 of `bytes` in one shot.
pub fn sha256_hex(bytes: &[u8]) -> String {
    let mut h = Sha256::new();
    h.update(bytes);
    to_hex(&h.finalize())
}

/// The registry address of `bytes`: `sha256:<64 lowercase hex>`.
pub fn digest_of(bytes: &[u8]) -> String {
    format!("{ALGORITHM}:{}", sha256_hex(bytes))
}

/// Lowercase-hex rendering of a raw digest.
pub fn to_hex(bytes: &[u8]) -> String {
    let mut out = String::with_capacity(bytes.len() * 2);
    for &b in bytes {
        out.push(char::from_digit((b >> 4) as u32, 16).unwrap_or('0'));
        out.push(char::from_digit((b & 0xf) as u32, 16).unwrap_or('0'));
    }
    out
}

/// Is `s` a well-formed registry digest (`sha256:` + 64 lowercase hex)?
/// Enforced before any digest coming off the wire or a tag file touches
/// the filesystem, the same way `cache::is_valid_id` guards job ids.
pub fn is_valid_digest(s: &str) -> bool {
    match s.split_once(':') {
        Some((alg, hex)) => {
            alg == ALGORITHM
                && hex.len() == 64
                && hex.bytes().all(|b| matches!(b, b'0'..=b'9' | b'a'..=b'f'))
        }
        None => false,
    }
}

/// Split a validated digest into its hex part, or fail loudly with the
/// offending string (truncated so a hostile "digest" cannot flood logs).
pub fn digest_hex(s: &str) -> Result<&str> {
    if !is_valid_digest(s) {
        let shown: String = s.chars().take(80).collect();
        return Err(Error::Artifact(format!(
            "malformed digest '{shown}' (want {ALGORITHM}:<64 lowercase hex>)"
        )));
    }
    match s.split_once(':') {
        Some((_, hex)) => Ok(hex),
        None => Err(Error::Artifact("malformed digest".to_string())),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// FIPS 180-4 example vectors plus the empty string (RFC 6234) —
    /// cross-checked against Python's hashlib.
    #[test]
    fn known_vectors() {
        assert_eq!(
            sha256_hex(b""),
            "e3b0c44298fc1c149afbf4c8996fb92427ae41e4649b934ca495991b7852b855"
        );
        assert_eq!(
            sha256_hex(b"abc"),
            "ba7816bf8f01cfea414140de5dae2223b00361a396177a9cb410ff61f20015ad"
        );
        assert_eq!(
            sha256_hex(b"abcdbcdecdefdefgefghfghighijhijkijkljklmklmnlmnomnopnopq"),
            "248d6a61d20638b8e5c026930c3e6039a33ce45964ff2167f6ecedd419db06c1"
        );
        // One full block of 'a' plus spill-over (padding straddles the
        // block boundary), from hashlib.
        assert_eq!(
            sha256_hex(&[b'a'; 100]),
            "2816597888e4a0d3a36b82b83316ab32680eb8f00f8cd3b904d681246d285a0e"
        );
    }

    #[test]
    fn streaming_matches_one_shot() {
        let data: Vec<u8> = (0u32..1000).map(|i| (i * 31 % 251) as u8).collect();
        let want = sha256_hex(&data);
        for chunk in [1usize, 7, 63, 64, 65, 128, 999] {
            let mut h = Sha256::new();
            for piece in data.chunks(chunk) {
                h.update(piece);
            }
            assert_eq!(to_hex(&h.finalize()), want, "chunk size {chunk}");
        }
    }

    #[test]
    fn digest_syntax_is_strict() {
        let good = digest_of(b"hello");
        assert!(is_valid_digest(&good));
        assert_eq!(digest_hex(&good).unwrap().len(), 64);
        for bad in [
            "",
            "sha256",
            "sha256:",
            "sha256:abc",
            "md5:ba7816bf8f01cfea414140de5dae2223b00361a396177a9cb410ff61f20015ad",
            "sha256:BA7816BF8F01CFEA414140DE5DAE2223B00361A396177A9CB410FF61F20015AD",
            "sha256:ba7816bf8f01cfea414140de5dae2223b00361a396177a9cb410ff61f20015a/",
            "sha256:ba7816bf8f01cfea414140de5dae2223b00361a396177a9cb410ff61f20015add",
        ] {
            assert!(!is_valid_digest(bad), "must reject '{bad}'");
            assert!(digest_hex(bad).is_err(), "must reject '{bad}'");
        }
    }
}
