//! Crate-wide error type.

use thiserror::Error;

/// Errors produced by the ising-dgx library.
#[derive(Error, Debug)]
pub enum Error {
    /// Lattice dimensions violate a layout constraint.
    #[error("invalid lattice geometry: {0}")]
    Geometry(String),

    /// Configuration file / value errors.
    #[error("config error: {0}")]
    Config(String),

    /// TOML syntax errors with line information.
    #[error("toml parse error at line {line}: {msg}")]
    Toml { line: usize, msg: String },

    /// JSON syntax errors with byte offset.
    #[error("json parse error at offset {offset}: {msg}")]
    Json { offset: usize, msg: String },

    /// Artifact manifest problems (missing program, shape mismatch, ...).
    #[error("artifact error: {0}")]
    Artifact(String),

    /// PJRT runtime failures (wraps the xla crate's error).
    #[error("runtime error: {0}")]
    Runtime(String),

    /// Coordinator-level failures (worker panic, halo mismatch, ...).
    #[error("coordinator error: {0}")]
    Coordinator(String),

    /// CLI usage errors.
    #[error("usage error: {0}")]
    Usage(String),

    /// Underlying I/O failure.
    #[error("io error: {0}")]
    Io(#[from] std::io::Error),
}

impl From<xla::Error> for Error {
    fn from(e: xla::Error) -> Self {
        Error::Runtime(e.to_string())
    }
}

/// Crate-wide result alias.
pub type Result<T> = std::result::Result<T, Error>;
