//! Crate-wide error type (std-only — the offline image has no `thiserror`,
//! so `Display`/`Error` are implemented by hand).

use std::fmt;

/// Errors produced by the ising-dgx library.
#[derive(Debug)]
pub enum Error {
    /// Lattice dimensions violate a layout constraint.
    Geometry(String),

    /// Configuration file / value errors.
    Config(String),

    /// TOML syntax errors with line information.
    Toml {
        /// 1-based source line.
        line: usize,
        /// Parser message.
        msg: String,
    },

    /// JSON syntax errors with byte offset.
    Json {
        /// Byte offset into the document.
        offset: usize,
        /// Parser message.
        msg: String,
    },

    /// Artifact manifest problems (missing program, shape mismatch, ...).
    Artifact(String),

    /// PJRT runtime failures (wraps the xla crate's error).
    Runtime(String),

    /// Coordinator-level failures (worker panic, halo mismatch, ...).
    Coordinator(String),

    /// Snapshot/checkpoint problems (bad magic, CRC mismatch, version or
    /// state inconsistencies).
    Snapshot(String),

    /// CLI usage errors.
    Usage(String),

    /// Underlying I/O failure.
    Io(std::io::Error),
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::Geometry(m) => write!(f, "invalid lattice geometry: {m}"),
            Error::Config(m) => write!(f, "config error: {m}"),
            Error::Toml { line, msg } => {
                write!(f, "toml parse error at line {line}: {msg}")
            }
            Error::Json { offset, msg } => {
                write!(f, "json parse error at offset {offset}: {msg}")
            }
            Error::Artifact(m) => write!(f, "artifact error: {m}"),
            Error::Runtime(m) => write!(f, "runtime error: {m}"),
            Error::Coordinator(m) => write!(f, "coordinator error: {m}"),
            Error::Snapshot(m) => write!(f, "snapshot error: {m}"),
            Error::Usage(m) => write!(f, "usage error: {m}"),
            Error::Io(e) => write!(f, "io error: {e}"),
        }
    }
}

impl std::error::Error for Error {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            Error::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Self {
        Error::Io(e)
    }
}

#[cfg(feature = "pjrt")]
impl From<xla::Error> for Error {
    fn from(e: xla::Error) -> Self {
        Error::Runtime(e.to_string())
    }
}

/// Crate-wide result alias.
pub type Result<T> = std::result::Result<T, Error>;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_messages_keep_their_prefixes() {
        assert_eq!(
            Error::Geometry("3x4".into()).to_string(),
            "invalid lattice geometry: 3x4"
        );
        assert_eq!(
            Error::Toml { line: 7, msg: "bad".into() }.to_string(),
            "toml parse error at line 7: bad"
        );
        assert_eq!(
            Error::Json { offset: 12, msg: "eof".into() }.to_string(),
            "json parse error at offset 12: eof"
        );
        assert!(Error::Usage("x".into()).to_string().starts_with("usage error"));
    }

    #[test]
    fn io_errors_convert_and_chain() {
        let io = std::io::Error::new(std::io::ErrorKind::NotFound, "gone");
        let e: Error = io.into();
        assert!(e.to_string().contains("gone"));
        assert!(std::error::Error::source(&e).is_some());
    }
}
