//! Farm checkpoint/restart — the layer that turns week-long, FPGA-scale
//! sweeps from a gamble into a supported scenario.
//!
//! A checkpoint directory holds:
//!
//! * `farm.json` — the manifest: the β × seed grid and measurement
//!   protocol this directory belongs to, plus the indices of completed
//!   replicas. Resuming validates the requested configuration against it,
//!   so a snapshot can never silently continue under different physics.
//! * `replica-NNNNN.snap` — one CRC-checked binary file per started
//!   replica (`util::snapshot`, kind [`KIND_REPLICA`]): the engine state
//!   (`EngineSnapshot`), the in-flight m/e sample series, and cumulative
//!   metrics. Files are written via temp + rename, so a `kill -9` between
//!   writes leaves the previous consistent state.
//!
//! Because each replica trajectory is a pure function of
//! `(geometry, β, seed, step)`, resuming from these files and finishing
//! the grid produces per-replica observable series **bit-identical** to
//! an uninterrupted run — asserted by `tests/integration_coordinator.rs`.

use super::farm::{FarmConfig, FarmEngine};
use super::metrics::Metrics;
use crate::error::{Error, Result};
use crate::util::json::{obj, Json};
use crate::util::snapshot::{
    read_file, write_file, ByteReader, ByteWriter, EngineSnapshot, KIND_BATCH,
    KIND_REPLICA,
};
use std::collections::BTreeSet;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, AtomicI64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

/// Manifest format version.
const MANIFEST_VERSION: usize = 1;

/// Manifest file name inside a checkpoint directory.
pub const MANIFEST_FILE: &str = "farm.json";

/// How a farm run should checkpoint itself.
#[derive(Clone, Debug)]
pub struct CheckpointSpec {
    /// Checkpoint directory (created if missing).
    pub dir: PathBuf,
    /// Snapshot each replica every this many samples (≥ 1; replicas are
    /// also snapshotted at completion and on interruption).
    pub every: u32,
    /// Continue an existing checkpoint directory instead of starting a
    /// fresh one. Refusing to overwrite without this flag protects a
    /// half-finished week of work from a mistyped command.
    pub resume: bool,
    /// Collect at most this many *new* samples across the whole farm in
    /// this invocation, then checkpoint and stop (time-boxed runs; also
    /// how the tests interrupt a farm deterministically). Batched units
    /// claim one budget token per sample *round* — a round yields one
    /// sample in each of the unit's (up to 64) lanes at once.
    pub sample_budget: Option<u64>,
    /// Cooperative stop flag shared with the caller (the serving
    /// scheduler's graceful-shutdown path). Once set, workers checkpoint
    /// their in-flight replicas and the farm returns
    /// [`FarmOutcome::Interrupted`](super::farm::FarmOutcome), exactly
    /// like an exhausted sample budget — so a restarted invocation
    /// resumes bit-identically.
    pub stop: Option<Arc<AtomicBool>>,
}

impl CheckpointSpec {
    /// Fresh-start spec with snapshot cadence `every`.
    pub fn new(dir: PathBuf, every: u32) -> Self {
        Self { dir, every, resume: false, sample_budget: None, stop: None }
    }
}

/// The manifest: grid + protocol fingerprint and completion record.
#[derive(Clone, Debug, PartialEq)]
pub struct Manifest {
    /// Engine family driving the replicas (`FarmEngine::name`):
    /// resuming a multispin farm with the tensor engine (or vice versa)
    /// is refused — snapshots carry different lattice payloads and the
    /// observables would not be comparable.
    pub engine: String,
    /// Lattice rows.
    pub h: usize,
    /// Lattice columns.
    pub w: usize,
    /// β grid as f32 bit patterns (exact, unlike decimal round-trips).
    pub betas_bits: Vec<u32>,
    /// Seed grid.
    pub seeds: Vec<u32>,
    /// Equilibration sweeps per replica.
    pub burn_in: u64,
    /// Measurement samples per replica.
    pub samples: usize,
    /// Sweeps between samples.
    pub thin: u64,
    /// Batch layout: replica lanes per batched work unit
    /// (`algorithms::batch::LANES`) when the engine groups same-β
    /// replicas into bit-plane batches; 0 for per-replica engines.
    /// Recording it pins the grouping a resume must reproduce.
    pub lanes: usize,
    /// Task indices of completed replicas (β-major grid order).
    pub done: BTreeSet<usize>,
}

impl Manifest {
    /// Fingerprint a farm configuration.
    pub fn from_config(cfg: &FarmConfig) -> Self {
        Self {
            engine: cfg.engine.name().to_string(),
            h: cfg.geom.h,
            w: cfg.geom.w,
            betas_bits: cfg.betas.iter().map(|b| b.to_bits()).collect(),
            seeds: cfg.seeds.clone(),
            burn_in: cfg.burn_in,
            samples: cfg.samples,
            thin: cfg.thin.max(1),
            lanes: if cfg.engine == FarmEngine::Batch {
                crate::algorithms::batch::LANES
            } else {
                0
            },
            done: BTreeSet::new(),
        }
    }

    /// Does this manifest describe the same grid + protocol?
    /// (Worker/shard counts are excluded on purpose: trajectories are
    /// partition-invariant, so resuming under a different parallel layout
    /// is legitimate and still bit-identical.)
    pub fn matches(&self, cfg: &FarmConfig) -> bool {
        let want = Self::from_config(cfg);
        self.engine == want.engine
            && self.h == want.h
            && self.w == want.w
            && self.betas_bits == want.betas_bits
            && self.seeds == want.seeds
            && self.burn_in == want.burn_in
            && self.samples == want.samples
            && self.thin == want.thin
            && self.lanes == want.lanes
    }

    /// Content-addressed fingerprint of the physics this manifest pins:
    /// engine family, geometry, exact β bit patterns, seed grid, and the
    /// measurement protocol. Execution layout (workers/shards) and the
    /// completion record (`done`) are excluded, matching
    /// [`Manifest::matches`] — two configs with the same fingerprint
    /// produce bit-identical observable series. This is the job key of
    /// the serving layer's result cache (16 lowercase hex chars, FNV-1a
    /// 64 over a length-prefixed field encoding).
    pub fn fingerprint(&self) -> String {
        let mut h: u64 = 0xcbf2_9ce4_8422_2325;
        let mut eat = |bytes: &[u8]| {
            for &b in bytes {
                h ^= b as u64;
                h = h.wrapping_mul(0x100_0000_01b3);
            }
        };
        eat(&(self.engine.len() as u64).to_le_bytes());
        eat(self.engine.as_bytes());
        eat(&(self.h as u64).to_le_bytes());
        eat(&(self.w as u64).to_le_bytes());
        eat(&(self.betas_bits.len() as u64).to_le_bytes());
        for &b in &self.betas_bits {
            eat(&b.to_le_bytes());
        }
        eat(&(self.seeds.len() as u64).to_le_bytes());
        for &s in &self.seeds {
            eat(&s.to_le_bytes());
        }
        eat(&self.burn_in.to_le_bytes());
        eat(&(self.samples as u64).to_le_bytes());
        eat(&self.thin.to_le_bytes());
        // Only batched manifests mix the lane width in, so every
        // pre-batch fingerprint (and the cached results keyed by it)
        // stays valid; the engine name already separates the families.
        if self.lanes > 0 {
            eat(&(self.lanes as u64).to_le_bytes());
        }
        format!("{h:016x}")
    }

    /// Serialize to the manifest JSON document.
    pub fn to_json(&self) -> Json {
        obj(vec![
            ("version", Json::Num(MANIFEST_VERSION as f64)),
            ("engine", Json::Str(self.engine.clone())),
            ("h", Json::Num(self.h as f64)),
            ("w", Json::Num(self.w as f64)),
            (
                "betas_bits",
                Json::Arr(self.betas_bits.iter().map(|&b| Json::Num(b as f64)).collect()),
            ),
            (
                "seeds",
                Json::Arr(self.seeds.iter().map(|&s| Json::Num(s as f64)).collect()),
            ),
            ("burn_in", Json::Num(self.burn_in as f64)),
            ("samples", Json::Num(self.samples as f64)),
            ("thin", Json::Num(self.thin as f64)),
            ("lanes", Json::Num(self.lanes as f64)),
            (
                "done",
                Json::Arr(self.done.iter().map(|&i| Json::Num(i as f64)).collect()),
            ),
        ])
    }

    /// Parse from the manifest JSON document.
    pub fn from_json(doc: &Json) -> Result<Self> {
        let version = doc.field("version")?.as_usize()?;
        if version != MANIFEST_VERSION {
            return Err(Error::Snapshot(format!(
                "unsupported manifest version {version} (this build reads {MANIFEST_VERSION})"
            )));
        }
        let nums = |key: &str| -> Result<Vec<u32>> {
            doc.field(key)?
                .as_arr()?
                .iter()
                .map(|v| v.as_usize().map(|n| n as u32))
                .collect()
        };
        // Manifests written before the tensor farm landed carry no
        // engine field; they were all multispin.
        let engine = match doc.field("engine") {
            Ok(v) => v.as_str()?.to_string(),
            Err(_) => "multispin".to_string(),
        };
        Ok(Self {
            engine,
            h: doc.field("h")?.as_usize()?,
            w: doc.field("w")?.as_usize()?,
            betas_bits: nums("betas_bits")?,
            seeds: nums("seeds")?,
            burn_in: doc.field("burn_in")?.as_usize()? as u64,
            samples: doc.field("samples")?.as_usize()?,
            thin: doc.field("thin")?.as_usize()? as u64,
            // Manifests written before the batch engine landed carry no
            // lanes field; they were all per-replica farms.
            lanes: match doc.field("lanes") {
                Ok(v) => v.as_usize()?,
                Err(_) => 0,
            },
            done: doc
                .field("done")?
                .as_arr()?
                .iter()
                .map(|v| v.as_usize())
                .collect::<Result<BTreeSet<usize>>>()?,
        })
    }

    fn store(&self, path: &Path) -> Result<()> {
        crate::util::snapshot::atomic_write(path, self.to_json().to_string_pretty().as_bytes())
    }

    fn load(path: &Path) -> Result<Self> {
        let text = std::fs::read_to_string(path)?;
        Self::from_json(&Json::parse(&text)?)
    }
}

/// One replica's persisted progress: engine state + in-flight series +
/// cumulative metrics.
#[derive(Clone, Debug)]
pub struct ReplicaProgress {
    /// Restorable engine state (lattice, β, seed, step).
    pub engine: EngineSnapshot,
    /// Magnetization samples collected so far.
    pub m_series: Vec<f64>,
    /// Energy samples collected so far.
    pub e_series: Vec<f64>,
    /// Cumulative throughput accounting across restarts.
    pub metrics: Metrics,
}

impl ReplicaProgress {
    /// Encode as a `KIND_REPLICA` payload.
    pub fn encode(&self) -> Vec<u8> {
        let engine = self.engine.encode();
        let mut wr = ByteWriter::new();
        wr.put_u64(engine.len() as u64);
        wr.put_bytes(&engine);
        wr.put_u64(self.m_series.len() as u64);
        wr.put_f64_slice(&self.m_series);
        wr.put_f64_slice(&self.e_series);
        wr.put_u64(self.metrics.flips);
        wr.put_u64(self.metrics.sweeps);
        wr.put_u64(self.metrics.elapsed.as_nanos() as u64);
        wr.into_bytes()
    }

    /// Decode a `KIND_REPLICA` payload.
    pub fn decode(bytes: &[u8]) -> Result<Self> {
        let mut r = ByteReader::new(bytes);
        let engine_len = r.get_u64()? as usize;
        let engine = EngineSnapshot::decode(r.get_bytes(engine_len)?)?;
        let n = r.get_u64()? as usize;
        let m_series = r.get_f64_vec(n)?;
        let e_series = r.get_f64_vec(n)?;
        let mut metrics = Metrics::new();
        metrics.flips = r.get_u64()?;
        metrics.sweeps = r.get_u64()?;
        metrics.elapsed = Duration::from_nanos(r.get_u64()?);
        r.finish()?;
        Ok(Self { engine, m_series, e_series, metrics })
    }
}

/// One batched work unit's persisted progress: the 64-lane engine state
/// plus every lane's in-flight sample series and the batch's cumulative
/// metrics (`KIND_BATCH` payload, stored under the unit's *first* task
/// index). All lanes advance in lockstep, so the series share one
/// length and one file resumes the whole group — per-lane resume falls
/// out of the deterministic grouping the manifest pins.
#[derive(Clone, Debug)]
pub struct BatchProgress {
    /// Restorable 64-lane engine state (bit planes, β, stream seed,
    /// step).
    pub engine: EngineSnapshot,
    /// Per-lane magnetization samples collected so far.
    pub m_lanes: Vec<Vec<f64>>,
    /// Per-lane energy samples collected so far.
    pub e_lanes: Vec<Vec<f64>>,
    /// Cumulative batch throughput accounting across restarts.
    pub metrics: Metrics,
}

impl BatchProgress {
    /// Encode as a `KIND_BATCH` payload.
    pub fn encode(&self) -> Vec<u8> {
        let engine = self.engine.encode();
        let mut wr = ByteWriter::new();
        wr.put_u64(engine.len() as u64);
        wr.put_bytes(&engine);
        wr.put_u64(self.m_lanes.len() as u64);
        wr.put_u64(self.m_lanes.first().map(|s| s.len()).unwrap_or(0) as u64);
        for series in &self.m_lanes {
            wr.put_f64_slice(series);
        }
        for series in &self.e_lanes {
            wr.put_f64_slice(series);
        }
        wr.put_u64(self.metrics.flips);
        wr.put_u64(self.metrics.sweeps);
        wr.put_u64(self.metrics.elapsed.as_nanos() as u64);
        wr.into_bytes()
    }

    /// Decode a `KIND_BATCH` payload.
    pub fn decode(bytes: &[u8]) -> Result<Self> {
        let mut r = ByteReader::new(bytes);
        let engine_len = r.get_u64()? as usize;
        let engine = EngineSnapshot::decode(r.get_bytes(engine_len)?)?;
        let lanes = r.get_u64()? as usize;
        if lanes == 0 || lanes > crate::algorithms::batch::LANES {
            return Err(Error::Snapshot(format!(
                "batch progress claims {lanes} replica lanes"
            )));
        }
        let n = r.get_u64()? as usize;
        let mut m_lanes = Vec::with_capacity(lanes);
        for _ in 0..lanes {
            m_lanes.push(r.get_f64_vec(n)?);
        }
        let mut e_lanes = Vec::with_capacity(lanes);
        for _ in 0..lanes {
            e_lanes.push(r.get_f64_vec(n)?);
        }
        let mut metrics = Metrics::new();
        metrics.flips = r.get_u64()?;
        metrics.sweeps = r.get_u64()?;
        metrics.elapsed = Duration::from_nanos(r.get_u64()?);
        r.finish()?;
        Ok(Self { engine, m_lanes, e_lanes, metrics })
    }
}

/// Enumerate the replica/batch snapshot files of a checkpoint
/// directory, sorted by file name (which is index order, thanks to the
/// zero-padded `replica-NNNNN.snap` naming). This is the file set the
/// artifact registry packages when a checkpoint is pushed as a layered
/// artifact (`registry::pack_checkpoint`); anything that is not a
/// snapshot file — the manifest, temp files, stray notes — is excluded.
pub fn snapshot_files(dir: &Path) -> Result<Vec<PathBuf>> {
    let mut out = Vec::new();
    for entry in std::fs::read_dir(dir)? {
        let entry = entry?;
        let name = entry.file_name();
        let Some(name) = name.to_str() else { continue };
        if name.starts_with("replica-") && name.ends_with(".snap") && entry.path().is_file() {
            out.push(entry.path());
        }
    }
    out.sort();
    Ok(out)
}

/// Shared checkpointing state for one farm invocation (thread-safe: the
/// farm's scoped workers all hold `&Checkpointer`).
pub struct Checkpointer {
    dir: PathBuf,
    every: u32,
    budget: Option<AtomicI64>,
    stop: Option<Arc<AtomicBool>>,
    manifest: Mutex<Manifest>,
}

impl Checkpointer {
    /// Open (or create) a checkpoint directory for `cfg` as described by
    /// `spec`. Fresh starts refuse a directory that already has a
    /// manifest; resumes require one and validate it against `cfg`.
    pub fn open(spec: &CheckpointSpec, cfg: &FarmConfig) -> Result<Self> {
        std::fs::create_dir_all(&spec.dir)?;
        let path = spec.dir.join(MANIFEST_FILE);
        let manifest = if path.exists() {
            if !spec.resume {
                return Err(Error::Usage(format!(
                    "checkpoint dir '{}' already holds a farm manifest; \
                     pass --resume to continue it or choose a fresh dir",
                    spec.dir.display()
                )));
            }
            let m = Manifest::load(&path)?;
            if !m.matches(cfg) {
                // Name the engine mismatch specifically: "resumed with
                // the wrong --engine" is the easy mistake to make, and a
                // generic grid/protocol message sends the user off to
                // re-check betas instead of the flag.
                let want = Manifest::from_config(cfg);
                if m.engine != want.engine {
                    return Err(Error::Snapshot(format!(
                        "checkpoint manifest '{}' was written by an \
                         '--engine {}' farm; this invocation runs \
                         '--engine {}' — refusing to resume",
                        path.display(),
                        m.engine,
                        want.engine
                    )));
                }
                return Err(Error::Snapshot(format!(
                    "checkpoint manifest '{}' describes a different farm \
                     (grid or protocol mismatch); refusing to resume",
                    path.display()
                )));
            }
            m
        } else {
            if spec.resume {
                return Err(Error::Usage(format!(
                    "--resume: no '{MANIFEST_FILE}' in checkpoint dir '{}'",
                    spec.dir.display()
                )));
            }
            let m = Manifest::from_config(cfg);
            m.store(&path)?;
            m
        };
        Ok(Self {
            dir: spec.dir.clone(),
            every: spec.every.max(1),
            budget: spec.sample_budget.map(|n| AtomicI64::new(n.min(i64::MAX as u64) as i64)),
            stop: spec.stop.clone(),
            manifest: Mutex::new(manifest),
        })
    }

    /// Checkpoint directory.
    pub fn dir(&self) -> &Path {
        &self.dir
    }

    /// Snapshot cadence in samples (normalized ≥ 1).
    pub fn every(&self) -> u32 {
        self.every
    }

    /// Replica snapshot path for grid task `idx`.
    pub fn replica_path(&self, idx: usize) -> PathBuf {
        self.dir.join(format!("replica-{idx:05}.snap"))
    }

    /// Every snapshot file currently in this checkpoint directory, in
    /// index order (see the free function [`snapshot_files`]).
    pub fn snapshot_files(&self) -> Result<Vec<PathBuf>> {
        snapshot_files(&self.dir)
    }

    /// Was a cooperative stop requested? (Never true without a flag.)
    pub fn stop_requested(&self) -> bool {
        self.stop
            .as_ref()
            .map(|s| s.load(Ordering::Relaxed))
            .unwrap_or(false)
    }

    /// Should workers pause? True once the sample budget runs out *or*
    /// the cooperative stop flag is raised (both paths checkpoint and
    /// surface as an interrupted farm).
    pub fn budget_exhausted(&self) -> bool {
        self.stop_requested()
            || self
                .budget
                .as_ref()
                .map(|b| b.load(Ordering::Relaxed) <= 0)
                .unwrap_or(false)
    }

    /// Claim one sample from the budget; `false` means stop and pause.
    pub fn take_sample(&self) -> bool {
        if self.stop_requested() {
            return false;
        }
        match &self.budget {
            None => true,
            Some(b) => b.fetch_sub(1, Ordering::Relaxed) > 0,
        }
    }

    /// Is a periodic snapshot due after `samples_done` samples?
    pub fn due(&self, samples_done: usize) -> bool {
        samples_done % self.every as usize == 0
    }

    /// Persist one replica's progress (atomic write). Takes the engine
    /// state as a plain [`EngineSnapshot`], so any engine family the
    /// farm drives (multispin clusters, tensor engines) checkpoints
    /// through the same path.
    pub fn save_replica(
        &self,
        idx: usize,
        engine: EngineSnapshot,
        metrics: &Metrics,
        m_series: &[f64],
        e_series: &[f64],
    ) -> Result<()> {
        let progress = ReplicaProgress {
            engine,
            m_series: m_series.to_vec(),
            e_series: e_series.to_vec(),
            metrics: metrics.clone(),
        };
        write_file(&self.replica_path(idx), KIND_REPLICA, &progress.encode())
    }

    /// Load and validate one replica's progress; `None` if the replica
    /// was never started. Validation cross-checks the snapshot against
    /// the grid task `(β, seed)` and the measurement protocol, so a
    /// misplaced or corrupted file fails loudly instead of diverging.
    pub fn load_replica(
        &self,
        idx: usize,
        cfg: &FarmConfig,
        beta: f32,
        seed: u32,
    ) -> Result<Option<ReplicaProgress>> {
        let path = self.replica_path(idx);
        if !path.exists() {
            return Ok(None);
        }
        let progress = ReplicaProgress::decode(&read_file(&path, KIND_REPLICA)?)?;
        let snap = &progress.engine;
        if snap.h != cfg.geom.h || snap.w != cfg.geom.w {
            return Err(Error::Snapshot(format!(
                "replica {idx}: snapshot is {}x{}, farm wants {}x{}",
                snap.h, snap.w, cfg.geom.h, cfg.geom.w
            )));
        }
        if snap.beta_bits != beta.to_bits() || snap.seed != seed {
            return Err(Error::Snapshot(format!(
                "replica {idx}: snapshot is (β bits {:08x}, seed {}), \
                 grid task wants (β bits {:08x}, seed {seed})",
                snap.beta_bits,
                snap.seed,
                beta.to_bits()
            )));
        }
        let n = progress.m_series.len();
        if progress.e_series.len() != n || n > cfg.samples {
            return Err(Error::Snapshot(format!(
                "replica {idx}: inconsistent sample series ({n} m, {} e, {} max)",
                progress.e_series.len(),
                cfg.samples
            )));
        }
        let thin = cfg.thin.max(1);
        let consistent = if n == 0 {
            snap.step <= cfg.burn_in
        } else {
            snap.step == cfg.burn_in + n as u64 * thin
        };
        if !consistent {
            return Err(Error::Snapshot(format!(
                "replica {idx}: sweep counter {} does not match {n} samples \
                 under burn-in {} / thin {thin}",
                snap.step, cfg.burn_in
            )));
        }
        Ok(Some(progress))
    }

    /// Persist one batched unit's progress (atomic write) under its
    /// first task index.
    pub fn save_batch(
        &self,
        first_idx: usize,
        engine: EngineSnapshot,
        metrics: &Metrics,
        m_lanes: &[Vec<f64>],
        e_lanes: &[Vec<f64>],
    ) -> Result<()> {
        let progress = BatchProgress {
            engine,
            m_lanes: m_lanes.to_vec(),
            e_lanes: e_lanes.to_vec(),
            metrics: metrics.clone(),
        };
        write_file(&self.replica_path(first_idx), KIND_BATCH, &progress.encode())
    }

    /// Load and validate one batched unit's progress; `None` if the
    /// unit was never started. Validation cross-checks the snapshot
    /// against the unit identity — geometry, β, the shared stream seed
    /// (the unit's first lane seed), the lane count — and the
    /// measurement protocol, so a misplaced or corrupted file fails
    /// loudly instead of diverging.
    pub fn load_batch(
        &self,
        first_idx: usize,
        cfg: &FarmConfig,
        beta: f32,
        seeds: &[u32],
    ) -> Result<Option<BatchProgress>> {
        let path = self.replica_path(first_idx);
        if !path.exists() {
            return Ok(None);
        }
        let progress = BatchProgress::decode(&read_file(&path, KIND_BATCH)?)?;
        let snap = &progress.engine;
        if snap.h != cfg.geom.h || snap.w != cfg.geom.w {
            return Err(Error::Snapshot(format!(
                "batch unit {first_idx}: snapshot is {}x{}, farm wants {}x{}",
                snap.h, snap.w, cfg.geom.h, cfg.geom.w
            )));
        }
        if snap.beta_bits != beta.to_bits() || snap.seed != seeds[0] {
            return Err(Error::Snapshot(format!(
                "batch unit {first_idx}: snapshot is (β bits {:08x}, stream seed {}), \
                 unit wants (β bits {:08x}, stream seed {})",
                snap.beta_bits,
                snap.seed,
                beta.to_bits(),
                seeds[0]
            )));
        }
        if progress.m_lanes.len() != seeds.len() || progress.e_lanes.len() != seeds.len() {
            return Err(Error::Snapshot(format!(
                "batch unit {first_idx}: progress has {} lanes, unit has {}",
                progress.m_lanes.len(),
                seeds.len()
            )));
        }
        let n = progress.m_lanes[0].len();
        if progress
            .m_lanes
            .iter()
            .chain(&progress.e_lanes)
            .any(|s| s.len() != n)
            || n > cfg.samples
        {
            return Err(Error::Snapshot(format!(
                "batch unit {first_idx}: inconsistent lane series ({n} samples, {} max)",
                cfg.samples
            )));
        }
        let thin = cfg.thin.max(1);
        let consistent = if n == 0 {
            snap.step <= cfg.burn_in
        } else {
            snap.step == cfg.burn_in + n as u64 * thin
        };
        if !consistent {
            return Err(Error::Snapshot(format!(
                "batch unit {first_idx}: sweep counter {} does not match {n} samples \
                 under burn-in {} / thin {thin}",
                snap.step, cfg.burn_in
            )));
        }
        Ok(Some(progress))
    }

    /// Record a replica as complete in the manifest.
    pub fn mark_done(&self, idx: usize) -> Result<()> {
        self.mark_done_range(idx, 1)
    }

    /// Record `count` consecutive replicas (a batched unit's lanes) as
    /// complete — one manifest lock + one atomic rewrite for the whole
    /// group, not one per lane.
    pub fn mark_done_range(&self, first_idx: usize, count: usize) -> Result<()> {
        let mut m = self.manifest.lock().expect("manifest lock poisoned");
        let mut changed = false;
        for idx in first_idx..first_idx + count {
            changed |= m.done.insert(idx);
        }
        if changed {
            m.store(&self.dir.join(MANIFEST_FILE))?;
        }
        Ok(())
    }

    /// Completed-replica count recorded in the manifest.
    pub fn done_count(&self) -> usize {
        self.manifest.lock().expect("manifest lock poisoned").done.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::driver::NativeCluster;
    use crate::coordinator::farm::FarmEngine;
    use crate::lattice::Geometry;

    fn cfg() -> FarmConfig {
        FarmConfig {
            geom: Geometry::new(8, 32).unwrap(),
            betas: vec![0.40, 0.44],
            seeds: vec![1, 2],
            shards: 1,
            workers: 1,
            burn_in: 4,
            samples: 6,
            thin: 2,
            threaded_shards: false,
            threads: 1,
            engine: FarmEngine::Multispin,
        }
    }

    fn temp_dir(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir()
            .join(format!("ising-ckpt-unit-{tag}-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        dir
    }

    #[test]
    fn manifest_roundtrip_and_matching() {
        let cfg = cfg();
        let mut m = Manifest::from_config(&cfg);
        m.done.insert(3);
        let back = Manifest::from_json(&Json::parse(&m.to_json().to_string_pretty()).unwrap())
            .unwrap();
        assert_eq!(m, back);
        assert!(back.matches(&cfg));
        // A different grid must not match.
        let mut other = cfg.clone();
        other.betas.push(0.48);
        assert!(!back.matches(&other));
        let mut other = cfg.clone();
        other.samples += 1;
        assert!(!back.matches(&other));
        // A different engine family must not match.
        let mut other = cfg.clone();
        other.engine = FarmEngine::Tensor;
        assert!(!back.matches(&other));
        // Worker/shard layout is not part of the fingerprint.
        let mut other = cfg;
        other.workers = 7;
        other.shards = 2;
        assert!(back.matches(&other));
    }

    /// Pre-tensor manifests carry no `engine` field: they must load as
    /// multispin farms (back-compat for existing checkpoint dirs).
    #[test]
    fn engineless_manifest_defaults_to_multispin() {
        let cfg = cfg();
        let mut doc = Manifest::from_config(&cfg).to_json();
        match &mut doc {
            Json::Obj(fields) => {
                fields.remove("engine").expect("manifest records its engine");
            }
            other => panic!("manifest serializes to an object, got {other:?}"),
        }
        let back = Manifest::from_json(&doc).unwrap();
        assert_eq!(back.engine, "multispin");
        assert!(back.matches(&cfg));
    }

    #[test]
    fn replica_progress_roundtrip() {
        let cfg = cfg();
        let mut cluster = NativeCluster::hot(cfg.geom, 1, 0.40, 1).unwrap();
        cluster.threaded = false;
        cluster.run(6);
        let progress = ReplicaProgress {
            engine: cluster.snapshot(),
            m_series: vec![0.25, -0.5],
            e_series: vec![-1.0, -1.25],
            metrics: cluster.metrics.clone(),
        };
        let back = ReplicaProgress::decode(&progress.encode()).unwrap();
        assert_eq!(back.engine, progress.engine);
        assert_eq!(back.m_series, progress.m_series);
        assert_eq!(back.e_series, progress.e_series);
        assert_eq!(back.metrics.sweeps, 6);
        assert_eq!(back.metrics.flips, progress.metrics.flips);
        // Truncated payloads are rejected.
        let bytes = progress.encode();
        assert!(ReplicaProgress::decode(&bytes[..bytes.len() - 5]).is_err());
    }

    fn batch_cfg() -> FarmConfig {
        FarmConfig { engine: FarmEngine::Batch, shards: 1, ..cfg() }
    }

    /// Batched manifests record the lane layout; resuming a batch farm
    /// with a per-replica engine (or vice versa) is refused.
    #[test]
    fn manifest_records_batch_lanes() {
        let m = Manifest::from_config(&batch_cfg());
        assert_eq!(m.lanes, crate::algorithms::batch::LANES);
        let back = Manifest::from_json(&Json::parse(&m.to_json().to_string_pretty()).unwrap())
            .unwrap();
        assert_eq!(back, m);
        assert!(back.matches(&batch_cfg()));
        assert!(!back.matches(&cfg()));
        // Per-replica manifests record no lanes, and the batch engine
        // changes the fingerprint (per-replica fingerprints are
        // untouched by the new field).
        let plain = Manifest::from_config(&cfg());
        assert_eq!(plain.lanes, 0);
        assert_ne!(m.fingerprint(), plain.fingerprint());
    }

    #[test]
    fn batch_progress_roundtrip_and_validation() {
        use crate::algorithms::batch::BatchEngine;
        let cfg = batch_cfg();
        let seeds = [1u32, 2];
        let mut engine = BatchEngine::hot(cfg.geom, 0.40, &seeds).unwrap();
        engine.run(cfg.burn_in + 2 * cfg.thin);
        let mut metrics = Metrics::new();
        metrics.flips = 1234;
        metrics.sweeps = cfg.burn_in + 2 * cfg.thin;
        let m_lanes = vec![vec![0.1, 0.2], vec![-0.1, -0.2]];
        let e_lanes = vec![vec![-1.0, -1.1], vec![-1.2, -1.3]];
        let progress = BatchProgress {
            engine: engine.snapshot(),
            m_lanes: m_lanes.clone(),
            e_lanes: e_lanes.clone(),
            metrics: metrics.clone(),
        };
        let back = BatchProgress::decode(&progress.encode()).unwrap();
        assert_eq!(back.engine, progress.engine);
        assert_eq!(back.m_lanes, m_lanes);
        assert_eq!(back.e_lanes, e_lanes);
        assert_eq!(back.metrics.flips, 1234);
        // Truncated payloads are rejected.
        let bytes = progress.encode();
        assert!(BatchProgress::decode(&bytes[..bytes.len() - 5]).is_err());

        // save/load through the checkpointer validates unit identity.
        let dir = temp_dir("batch-identity");
        let c = Checkpointer::open(&CheckpointSpec::new(dir.clone(), 1), &cfg).unwrap();
        assert!(c.load_batch(0, &cfg, 0.40, &seeds).unwrap().is_none());
        c.save_batch(0, engine.snapshot(), &metrics, &m_lanes, &e_lanes).unwrap();
        let p = c.load_batch(0, &cfg, 0.40, &seeds).unwrap().expect("saved progress");
        assert_eq!(p.m_lanes, m_lanes);
        // Wrong unit identity fails loudly: wrong β, wrong stream seed,
        // wrong lane count.
        assert!(c.load_batch(0, &cfg, 0.44, &seeds).is_err());
        assert!(c.load_batch(0, &cfg, 0.40, &[7, 2]).is_err());
        assert!(c.load_batch(0, &cfg, 0.40, &[1, 2, 3]).is_err());
        // A per-replica file is not a batch file (kind mismatch).
        c.save_replica(1, engine.snapshot(), &metrics, &[0.1], &[-1.0]).unwrap();
        assert!(c.load_batch(1, &cfg, 0.40, &seeds).is_err());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn open_enforces_resume_discipline() {
        let cfg = cfg();
        let dir = temp_dir("discipline");
        // Resume without a manifest: error.
        let spec = CheckpointSpec { resume: true, ..CheckpointSpec::new(dir.clone(), 1) };
        assert!(Checkpointer::open(&spec, &cfg).is_err());
        // Fresh start writes the manifest.
        let spec = CheckpointSpec::new(dir.clone(), 2);
        let c = Checkpointer::open(&spec, &cfg).unwrap();
        assert_eq!(c.every(), 2);
        assert!(!c.budget_exhausted());
        // Starting again without --resume: refused.
        assert!(Checkpointer::open(&spec, &cfg).is_err());
        // Resume with a matching config: fine.
        let spec = CheckpointSpec { resume: true, ..spec };
        assert!(Checkpointer::open(&spec, &cfg).is_ok());
        // Resume with a different protocol: refused.
        let mut other = cfg;
        other.burn_in += 1;
        assert!(Checkpointer::open(&spec, &other).is_err());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn save_load_replica_validates_task_identity() {
        let cfg = cfg();
        let dir = temp_dir("identity");
        let c = Checkpointer::open(&CheckpointSpec::new(dir.clone(), 1), &cfg).unwrap();
        assert!(c.load_replica(0, &cfg, 0.40, 1).unwrap().is_none());

        let mut cluster = NativeCluster::hot(cfg.geom, 1, 0.40, 1).unwrap();
        cluster.threaded = false;
        cluster.run(cfg.burn_in + 2 * cfg.thin);
        c.save_replica(0, cluster.snapshot(), &cluster.metrics, &[0.1, 0.2], &[-1.0, -1.1])
            .unwrap();

        let p = c.load_replica(0, &cfg, 0.40, 1).unwrap().expect("saved progress");
        assert_eq!(p.m_series, vec![0.1, 0.2]);
        assert_eq!(p.engine.step, cfg.burn_in + 2 * cfg.thin);
        // Wrong task identity fails loudly.
        assert!(c.load_replica(0, &cfg, 0.44, 1).is_err());
        assert!(c.load_replica(0, &cfg, 0.40, 2).is_err());
        // Step/sample inconsistency fails loudly.
        c.save_replica(0, cluster.snapshot(), &cluster.metrics, &[0.1], &[-1.0]).unwrap();
        assert!(c.load_replica(0, &cfg, 0.40, 1).is_err());

        c.mark_done(0).unwrap();
        c.mark_done(0).unwrap();
        assert_eq!(c.done_count(), 1);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn fingerprint_tracks_physics_not_layout() {
        let base = Manifest::from_config(&cfg());
        let fp = base.fingerprint();
        assert_eq!(fp.len(), 16);
        assert!(fp.chars().all(|c| c.is_ascii_hexdigit() && !c.is_ascii_uppercase()));
        // Execution layout and completion state do not change the key.
        let mut layout = cfg();
        layout.workers = 9;
        layout.shards = 2;
        layout.threaded_shards = true;
        layout.threads = 4;
        assert_eq!(Manifest::from_config(&layout).fingerprint(), fp);
        let mut done = base.clone();
        done.done.insert(1);
        assert_eq!(done.fingerprint(), fp);
        // Every physics/protocol field does.
        let mutations: [fn(&mut FarmConfig); 7] = [
            |c| c.engine = FarmEngine::Tensor,
            |c| c.geom = Geometry::new(8, 64).unwrap(),
            |c| c.betas[0] = 0.41,
            |c| c.seeds.push(3),
            |c| c.burn_in += 1,
            |c| c.samples += 1,
            |c| c.thin += 1,
        ];
        for mutate in mutations {
            let mut other = cfg();
            mutate(&mut other);
            assert_ne!(Manifest::from_config(&other).fingerprint(), fp);
        }
    }

    #[test]
    fn stop_flag_pauses_like_an_exhausted_budget() {
        let cfg = cfg();
        let dir = temp_dir("stopflag");
        let stop = Arc::new(AtomicBool::new(false));
        let spec = CheckpointSpec {
            stop: Some(stop.clone()),
            ..CheckpointSpec::new(dir.clone(), 1)
        };
        let c = Checkpointer::open(&spec, &cfg).unwrap();
        assert!(!c.stop_requested());
        assert!(!c.budget_exhausted());
        assert!(c.take_sample());
        stop.store(true, Ordering::Relaxed);
        assert!(c.stop_requested());
        assert!(c.budget_exhausted());
        assert!(!c.take_sample());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn sample_budget_counts_down() {
        let cfg = cfg();
        let dir = temp_dir("budget");
        let spec = CheckpointSpec {
            sample_budget: Some(2),
            ..CheckpointSpec::new(dir.clone(), 1)
        };
        let c = Checkpointer::open(&spec, &cfg).unwrap();
        assert!(!c.budget_exhausted());
        assert!(c.take_sample());
        assert!(c.take_sample());
        assert!(!c.take_sample());
        assert!(c.budget_exhausted());
        let _ = std::fs::remove_dir_all(&dir);
    }
}
