//! Simulated device topology — the stand-in for the DGX-2's NVSwitch
//! fabric (DESIGN.md §2: the host has one CPU core, so multi-GPU timing is
//! produced by the calibrated event model in `perfmodel`, while slab
//! execution itself is real and bit-exact).

/// A device interconnect description.
#[derive(Clone, Copy, Debug)]
pub struct Interconnect {
    /// Per-direction link bandwidth in bytes/second.
    pub bandwidth: f64,
    /// Per-message latency in seconds.
    pub latency: f64,
}

/// NVLink through NVSwitch as in the DGX-2: 6 links × 25 GB/s per GPU.
pub const NVLINK_DGX2: Interconnect = Interconnect { bandwidth: 150e9, latency: 2e-6 };

/// Same-host memcpy (what halo exchange actually costs on this testbed).
pub const LOCAL_MEMCPY: Interconnect = Interconnect { bandwidth: 10e9, latency: 1e-7 };

/// A named multi-device system model.
#[derive(Clone, Copy, Debug)]
pub struct Topology {
    /// Human-readable name.
    pub name: &'static str,
    /// Number of devices.
    pub devices: usize,
    /// Per-device sustained spin-update throughput, flips/ns (the paper's
    /// headline unit), used to convert slab work to time.
    pub flips_per_ns: f64,
    /// Interconnect between slab neighbors.
    pub link: Interconnect,
}

impl Topology {
    /// DGX-2 (paper Table 3: 417.57 flips/ns per V100 on the optimized code).
    pub fn dgx2() -> Self {
        Self { name: "DGX-2", devices: 16, flips_per_ns: 417.57, link: NVLINK_DGX2 }
    }

    /// DGX-2H (paper Table 3: 453.56 flips/ns per GPU).
    pub fn dgx2h() -> Self {
        Self { name: "DGX-2H", devices: 16, flips_per_ns: 453.56, link: NVLINK_DGX2 }
    }

    /// This machine, calibrated from a measured single-worker rate.
    pub fn local(measured_flips_per_ns: f64, workers: usize) -> Self {
        Self {
            name: "local",
            devices: workers,
            flips_per_ns: measured_flips_per_ns,
            link: LOCAL_MEMCPY,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn presets_match_paper_numbers() {
        assert_eq!(Topology::dgx2().devices, 16);
        assert!((Topology::dgx2().flips_per_ns - 417.57).abs() < 1e-9);
        assert!((Topology::dgx2h().flips_per_ns - 453.56).abs() < 1e-9);
        assert!(Topology::dgx2().link.bandwidth > 1e11);
    }
}
