//! Slab decomposition (paper §4): the lattice is partitioned into
//! horizontal slabs, one per device, each stored in the same checkerboard
//! layout as the single-device case.

use crate::error::{Error, Result};
use crate::lattice::Geometry;

/// One device's slab.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Slab {
    /// Index of the owning device.
    pub device: usize,
    /// First global row.
    pub base_row: usize,
    /// Number of rows.
    pub height: usize,
}

impl Slab {
    /// Global row above this slab (periodic).
    pub fn row_above(&self, geom: Geometry) -> usize {
        (self.base_row + geom.h - 1) % geom.h
    }

    /// Global row below this slab (periodic).
    pub fn row_below(&self, geom: Geometry) -> usize {
        (self.base_row + self.height) % geom.h
    }
}

/// Partition `geom` into `n` equal slabs.
///
/// Heights must be even (the checkerboard parity rules and the tensor-core
/// row-parity split both require even slab bases) — callers get a clear
/// error otherwise.
pub fn partition(geom: Geometry, n: usize) -> Result<Vec<Slab>> {
    if n == 0 {
        return Err(Error::Coordinator("need at least one device".into()));
    }
    if geom.h % n != 0 {
        return Err(Error::Coordinator(format!(
            "lattice height {} not divisible by {n} devices",
            geom.h
        )));
    }
    let height = geom.h / n;
    if height % 2 != 0 {
        return Err(Error::Coordinator(format!(
            "slab height {height} must be even (checkerboard parity)"
        )));
    }
    Ok((0..n)
        .map(|device| Slab { device, base_row: device * height, height })
        .collect())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn partitions_cover_disjointly() {
        let g = Geometry::new(16, 32).unwrap();
        for n in [1, 2, 4, 8] {
            let slabs = partition(g, n).unwrap();
            assert_eq!(slabs.len(), n);
            let mut covered = vec![false; g.h];
            for s in &slabs {
                assert_eq!(s.base_row % 2, 0, "even bases");
                for r in s.base_row..s.base_row + s.height {
                    assert!(!covered[r], "overlap at row {r}");
                    covered[r] = true;
                }
            }
            assert!(covered.iter().all(|&c| c));
        }
    }

    #[test]
    fn halo_rows_are_periodic() {
        let g = Geometry::new(8, 32).unwrap();
        let slabs = partition(g, 2).unwrap();
        assert_eq!(slabs[0].row_above(g), 7);
        assert_eq!(slabs[0].row_below(g), 4);
        assert_eq!(slabs[1].row_above(g), 3);
        assert_eq!(slabs[1].row_below(g), 0);
    }

    #[test]
    fn rejects_bad_partitions() {
        let g = Geometry::new(8, 32).unwrap();
        assert!(partition(g, 0).is_err());
        assert!(partition(g, 3).is_err(), "8 % 3 != 0");
        let g12 = Geometry::new(12, 32).unwrap();
        assert!(partition(g12, 4).is_err(), "odd slab height 3");
        assert!(partition(g12, 2).is_ok());
    }
}
