//! Throughput metrics in the paper's units (flips/ns) plus per-phase
//! timing for the coordinator.

use crate::util::timer::PhaseTimes;
use std::time::Duration;

/// Accumulated metrics for a run.
#[derive(Debug, Default, Clone)]
pub struct Metrics {
    /// Total spin updates attempted.
    pub flips: u64,
    /// Total wall-clock spent in sweeps.
    pub elapsed: Duration,
    /// Per-phase breakdown (black/white/halo/dispatch...).
    pub phases: PhaseTimes,
    /// Sweeps completed.
    pub sweeps: u64,
}

impl Metrics {
    /// New empty metrics.
    pub fn new() -> Self {
        Self::default()
    }

    /// Record one sweep over `sites` spins taking `d`.
    pub fn record_sweep(&mut self, sites: u64, d: Duration) {
        self.flips += sites;
        self.elapsed += d;
        self.sweeps += 1;
    }

    /// Fold another accumulator into this one (farm/fleet aggregation):
    /// flips and sweeps add; `elapsed` becomes summed per-worker CPU sweep
    /// time, which callers divide by wall clock for parallel efficiency.
    pub fn merge(&mut self, other: &Metrics) {
        self.flips += other.flips;
        self.elapsed += other.elapsed;
        self.sweeps += other.sweeps;
        for (name, d) in other.phases.iter() {
            self.phases.add(name, d);
        }
    }

    /// The paper's headline metric.
    pub fn flips_per_ns(&self) -> f64 {
        crate::util::units::flips_per_ns(self.flips, self.elapsed.as_secs_f64())
    }

    /// Mean seconds per sweep.
    pub fn secs_per_sweep(&self) -> f64 {
        if self.sweeps == 0 {
            return f64::NAN;
        }
        self.elapsed.as_secs_f64() / self.sweeps as f64
    }

    /// One-line human summary.
    pub fn summary(&self) -> String {
        format!(
            "{} sweeps, {} flips, {:.3}s → {} flips/ns",
            self.sweeps,
            self.flips,
            self.elapsed.as_secs_f64(),
            crate::util::units::fmt_rate(self.flips_per_ns())
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accumulates_and_converts() {
        let mut m = Metrics::new();
        m.record_sweep(1_000_000, Duration::from_millis(1));
        m.record_sweep(1_000_000, Duration::from_millis(1));
        assert_eq!(m.flips, 2_000_000);
        assert_eq!(m.sweeps, 2);
        // 2e6 flips in 2e6 ns = 1 flip/ns.
        assert!((m.flips_per_ns() - 1.0).abs() < 1e-9);
        assert!((m.secs_per_sweep() - 0.001).abs() < 1e-9);
        assert!(m.summary().contains("flips/ns"));
    }

    #[test]
    fn merge_accumulates_including_phases() {
        let mut a = Metrics::new();
        a.record_sweep(100, Duration::from_millis(2));
        a.phases.add("black", Duration::from_millis(1));
        let mut b = Metrics::new();
        b.record_sweep(50, Duration::from_millis(1));
        b.phases.add("black", Duration::from_millis(3));
        b.phases.add("halo", Duration::from_millis(2));
        a.merge(&b);
        assert_eq!(a.flips, 150);
        assert_eq!(a.sweeps, 2);
        assert_eq!(a.elapsed, Duration::from_millis(3));
        let black = a.phases.iter().find(|(n, _)| *n == "black").unwrap().1;
        assert_eq!(black, Duration::from_millis(4));
        assert_eq!(a.phases.total(), Duration::from_millis(6));
    }
}
