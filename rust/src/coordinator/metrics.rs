//! Throughput metrics in the paper's units (flips/ns) plus per-phase
//! timing for the coordinator.

use crate::util::timer::PhaseTimes;
use std::time::Duration;

/// Accumulated metrics for a run.
#[derive(Debug, Default, Clone)]
pub struct Metrics {
    /// Total spin updates attempted.
    pub flips: u64,
    /// Total wall-clock spent in sweeps.
    pub elapsed: Duration,
    /// Per-phase breakdown (black/white/halo/dispatch...).
    pub phases: PhaseTimes,
    /// Sweeps completed.
    pub sweeps: u64,
}

impl Metrics {
    /// New empty metrics.
    pub fn new() -> Self {
        Self::default()
    }

    /// Record one sweep over `sites` spins taking `d`.
    pub fn record_sweep(&mut self, sites: u64, d: Duration) {
        self.flips += sites;
        self.elapsed += d;
        self.sweeps += 1;
    }

    /// The paper's headline metric.
    pub fn flips_per_ns(&self) -> f64 {
        crate::util::units::flips_per_ns(self.flips, self.elapsed.as_secs_f64())
    }

    /// Mean seconds per sweep.
    pub fn secs_per_sweep(&self) -> f64 {
        if self.sweeps == 0 {
            return f64::NAN;
        }
        self.elapsed.as_secs_f64() / self.sweeps as f64
    }

    /// One-line human summary.
    pub fn summary(&self) -> String {
        format!(
            "{} sweeps, {} flips, {:.3}s → {} flips/ns",
            self.sweeps,
            self.flips,
            self.elapsed.as_secs_f64(),
            crate::util::units::fmt_sig(self.flips_per_ns(), 4)
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accumulates_and_converts() {
        let mut m = Metrics::new();
        m.record_sweep(1_000_000, Duration::from_millis(1));
        m.record_sweep(1_000_000, Duration::from_millis(1));
        assert_eq!(m.flips, 2_000_000);
        assert_eq!(m.sweeps, 2);
        // 2e6 flips in 2e6 ns = 1 flip/ns.
        assert!((m.flips_per_ns() - 1.0).abs() < 1e-9);
        assert!((m.secs_per_sweep() - 0.001).abs() < 1e-9);
        assert!(m.summary().contains("flips/ns"));
    }
}
