//! Discrete-event performance model of slab-parallel sweeps on a
//! multi-device system (DESIGN.md §2 substitution for the 16-GPU DGX-2).
//!
//! The model is first-principles, not curve-fit: a sweep is two color
//! phases; in each phase every device updates its slab's half-lattice
//! (`spins/2` flips at the device rate) and then exchanges one boundary
//! row per neighbor (2 messages of `w/2` spins at the modeled bit width).
//! Linear scaling falls out *because* halo bytes ≪ bulk flips — the same
//! reason the paper gives — and the crossover where communication would
//! bite is visible by shrinking the lattice.

use super::topology::Topology;

/// Bits per spin on the wire/in memory for a given implementation.
#[derive(Clone, Copy, Debug)]
pub enum SpinWidth {
    /// Byte per spin (basic / tensor-core implementations).
    Byte,
    /// 4-bit multi-spin coding (optimized implementation).
    Nibble,
}

impl SpinWidth {
    fn bytes(&self, spins: f64) -> f64 {
        match self {
            SpinWidth::Byte => spins,
            SpinWidth::Nibble => spins / 2.0,
        }
    }
}

/// Modeled timing for one configuration.
#[derive(Clone, Copy, Debug)]
pub struct ModelResult {
    /// Seconds per full sweep.
    pub sweep_secs: f64,
    /// Aggregate throughput in flips/ns.
    pub flips_per_ns: f64,
    /// Fraction of sweep time spent in halo exchange.
    pub comm_fraction: f64,
}

/// Model one sweep of an `lat_h × lat_w` lattice over `n` devices.
pub fn model_sweep(
    topo: &Topology,
    width: SpinWidth,
    lat_h: usize,
    lat_w: usize,
    n: usize,
) -> ModelResult {
    assert!(n >= 1);
    let spins = lat_h as f64 * lat_w as f64;
    let per_dev = spins / n as f64;
    // Bulk: each spin is updated once per sweep (two half-phases).
    let t_bulk = per_dev / (topo.flips_per_ns * 1e9);
    // Comm: per phase, each device sends/receives one boundary row of each
    // color-plane to each of two neighbors; with one-hop NVSwitch routing
    // the two directions overlap, so count 2 messages of w/2 spins each,
    // twice per sweep. n == 1 needs no exchange (wrap is local).
    let t_comm = if n > 1 {
        let row_bytes = width.bytes(lat_w as f64 / 2.0);
        2.0 * (2.0 * (row_bytes / topo.link.bandwidth + topo.link.latency))
    } else {
        0.0
    };
    let sweep_secs = t_bulk + t_comm;
    ModelResult {
        sweep_secs,
        flips_per_ns: spins / (sweep_secs * 1e9),
        comm_fraction: t_comm / sweep_secs,
    }
}

/// Weak scaling: per-device lattice fixed at `h_per × w`, devices 1..=n.
pub fn weak_scaling(
    topo: &Topology,
    width: SpinWidth,
    h_per: usize,
    w: usize,
    ns: &[usize],
) -> Vec<(usize, ModelResult)> {
    ns.iter()
        .map(|&n| (n, model_sweep(topo, width, h_per * n, w, n)))
        .collect()
}

/// Strong scaling: total lattice fixed, devices 1..=n.
pub fn strong_scaling(
    topo: &Topology,
    width: SpinWidth,
    h: usize,
    w: usize,
    ns: &[usize],
) -> Vec<(usize, ModelResult)> {
    ns.iter()
        .map(|&n| (n, model_sweep(topo, width, h, w, n)))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Paper Table 3 shape: weak scaling on the DGX-2 is essentially
    /// linear for the 30 GB/GPU lattice (paper: 6474.16 at 16 GPUs from
    /// 417.57 at one → 96.9% efficiency; the first-principles model gives
    /// ≥ 96% too — comm is negligible at this size).
    #[test]
    fn weak_scaling_is_linear_at_paper_size() {
        let topo = Topology::dgx2();
        let l = 123 * 2048;
        let res = weak_scaling(&topo, SpinWidth::Nibble, l, l, &[1, 16]);
        let r1 = res[0].1.flips_per_ns;
        let r16 = res[1].1.flips_per_ns;
        assert!((r1 - 417.57).abs() / 417.57 < 1e-6);
        let eff = r16 / (16.0 * r1);
        assert!(eff > 0.96, "efficiency {eff}");
        assert!(res[1].1.comm_fraction < 0.05);
    }

    /// Strong-scaling sanity: paper Table 4 reaches 6474.16/417.57 ≈ 15.5×
    /// at 16 GPUs on the fixed (123·2048)² lattice.
    #[test]
    fn strong_scaling_matches_paper_shape() {
        let topo = Topology::dgx2();
        let l = 123 * 2048;
        let res = strong_scaling(&topo, SpinWidth::Nibble, l, l, &[1, 2, 4, 8, 16]);
        let base = res[0].1.flips_per_ns;
        let speedup16 = res[4].1.flips_per_ns / base;
        assert!(speedup16 > 15.0 && speedup16 <= 16.0, "speedup {speedup16}");
        // Monotone increasing.
        for w in res.windows(2) {
            assert!(w[1].1.flips_per_ns > w[0].1.flips_per_ns);
        }
    }

    /// Communication must dominate when the lattice is tiny — the model
    /// has a real crossover, it is not hard-wired linear.
    #[test]
    fn tiny_lattices_hit_the_comm_wall() {
        let topo = Topology::dgx2();
        let res = model_sweep(&topo, SpinWidth::Nibble, 128, 128, 16);
        assert!(res.comm_fraction > 0.5, "comm fraction {}", res.comm_fraction);
        // And scaling efficiency collapses.
        let r1 = model_sweep(&topo, SpinWidth::Nibble, 128, 128, 1);
        assert!(res.flips_per_ns < 4.0 * r1.flips_per_ns);
    }

    /// Byte-wide spins double the halo bytes.
    #[test]
    fn spin_width_affects_comm() {
        let topo = Topology::dgx2();
        let a = model_sweep(&topo, SpinWidth::Byte, 4096, 4096, 16);
        let b = model_sweep(&topo, SpinWidth::Nibble, 4096, 4096, 16);
        assert!(a.comm_fraction > b.comm_fraction);
    }
}
