//! The multi-device coordinator (paper §4): slab decomposition, halo
//! exchange, two-phase color scheduling, throughput metrics, the parallel
//! replica farm (the Fig. 5/6 production workload) with its
//! checkpoint/restart layer (long runs survive kills and resume
//! bit-identically), and the calibrated DGX-2 performance model that
//! substitutes for hardware this testbed does not have (DESIGN.md §2).

pub mod checkpoint;
pub mod driver;
pub mod farm;
pub mod metrics;
pub mod partition;
pub mod perfmodel;
pub mod topology;

pub use checkpoint::{BatchProgress, CheckpointSpec, Checkpointer, Manifest, ReplicaProgress};
pub use driver::NativeCluster;
#[cfg(feature = "pjrt")]
pub use driver::SlabCluster;
pub use farm::{
    default_beta_grid, run_farm, run_farm_checkpointed, work_units, FarmConfig, FarmEngine,
    FarmOutcome, FarmResult, ReplicaResult, WorkUnit,
};
pub use metrics::Metrics;
pub use partition::{partition, Slab};
pub use perfmodel::{model_sweep, strong_scaling, weak_scaling, ModelResult, SpinWidth};
pub use topology::Topology;
