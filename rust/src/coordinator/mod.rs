//! The multi-device coordinator (paper §4): slab decomposition, halo
//! exchange, two-phase color scheduling, throughput metrics, and the
//! calibrated DGX-2 performance model that substitutes for hardware this
//! testbed does not have (DESIGN.md §2).

pub mod driver;
pub mod metrics;
pub mod partition;
pub mod perfmodel;
pub mod topology;

pub use driver::{NativeCluster, SlabCluster};
pub use metrics::Metrics;
pub use partition::{partition, Slab};
pub use perfmodel::{model_sweep, strong_scaling, weak_scaling, ModelResult, SpinWidth};
pub use topology::Topology;
