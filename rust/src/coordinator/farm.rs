//! Parallel replica farm — the Fig. 5 / Fig. 6 production workload: R
//! independent replicas over a seed × β grid, each driving its own sharded
//! [`NativeCluster`], executed by a pool of scoped worker threads.
//!
//! Replicas are the parallelism unit (they are embarrassingly parallel and
//! saturate cores without the halo coordination the in-replica shard
//! threads need), so by default each replica's cluster runs its shards
//! sequentially and the farm scales by running many replicas at once.
//! Every replica trajectory is a pure function of `(geometry, β, seed)` —
//! `NativeCluster` is partition-invariant by construction — so results are
//! bit-identical for any worker count, which the integration tests assert.

use super::checkpoint::{CheckpointSpec, Checkpointer};
use super::driver::NativeCluster;
use super::metrics::Metrics;
use crate::algorithms::batch::{self, BatchEngine};
use crate::algorithms::metropolis::ScalarEngine;
use crate::algorithms::sweeper::Sweeper;
use crate::algorithms::DomainEngine;
use crate::error::{Error, Result};
use crate::lattice::Geometry;
use crate::observables::binder::BinderAccumulator;
use crate::observables::stats;
use crate::tensor::{Precision, TensorEngine};
use crate::util::snapshot::EngineSnapshot;
use crate::util::timer::Timer;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;
use std::time::Duration;

/// The inverse critical temperature β_c = ln(1 + √2)/2 as f32 (the grid
/// default centers on the transition, like the paper's Fig. 5/6 scans).
pub const BETA_C: f32 = 0.4406868;

/// A β grid of `n` points spanning the critical window (0.36..0.52).
pub fn default_beta_grid(n: usize) -> Vec<f32> {
    let n = n.max(1);
    if n == 1 {
        return vec![BETA_C];
    }
    let (lo, hi) = (0.36f32, 0.52f32);
    (0..n)
        .map(|i| lo + (hi - lo) * i as f32 / (n - 1) as f32)
        .collect()
}

/// Which engine family drives each replica of the farm.
///
/// The farm's parallelism unit is the replica, so any deterministic
/// single-replica engine slots in; the per-replica families are the
/// optimized multi-spin cluster (the paper's §3.3 production path) and
/// the tensor (stencil-as-GEMM) engine of §3.2. Both follow the shared
/// Philox site-group convention, so for the same `(geometry, β, seed)`
/// they produce **bit-identical observable series** — asserted by the
/// farm integration tests. The batch family instead advances up to 64
/// same-β replicas per worker in lockstep with one shared draw per
/// site — an order-of-magnitude throughput lever with its own
/// (documented, tested) lane convention.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FarmEngine {
    /// Reference byte-plane [`ScalarEngine`] — the §3.1 baseline, one
    /// thread per replica.
    Scalar,
    /// Domain-decomposed [`DomainEngine`]: one lattice per replica split
    /// into `threads` slabs with halo-row exchange (§4). Trajectories
    /// are thread-count-invariant, so `threads` is execution layout
    /// like `workers`, excluded from the manifest fingerprint.
    Domain,
    /// Sharded [`NativeCluster`] over the packed multi-spin lattice.
    Multispin,
    /// [`TensorEngine`] (banded-GEMM neighbor sums, f32 mode).
    Tensor,
    /// Replica-batched [`BatchEngine`]: the farm groups up to 64 same-β
    /// replicas into one bit-plane engine and advances them in lockstep
    /// (Block et al., arXiv:1007.3726). One shared Philox draw per site
    /// drives every lane; lanes decorrelate by initial-condition seed,
    /// so batched trajectories follow their own (documented) RNG
    /// convention rather than matching per-replica runs.
    Batch,
}

impl FarmEngine {
    /// Manifest/fingerprint name. Parsing goes through the canonical
    /// engine registry (`config::ENGINES`) via [`FarmEngine::parse`],
    /// not through a second name table here.
    pub fn name(self) -> &'static str {
        match self {
            FarmEngine::Scalar => "scalar",
            FarmEngine::Domain => "domain",
            FarmEngine::Multispin => "multispin",
            FarmEngine::Tensor => "tensor",
            FarmEngine::Batch => "batch",
        }
    }

    /// Map an engine name (parsed against the canonical registry,
    /// aliases included) onto the farm's engine families — shared by the
    /// `ising sweep` CLI and the job API of `ising serve`.
    pub fn parse(s: &str) -> Result<Self> {
        use crate::config::EngineKind;
        match EngineKind::parse(s)? {
            EngineKind::NativeScalar => Ok(FarmEngine::Scalar),
            EngineKind::NativeDomain => Ok(FarmEngine::Domain),
            EngineKind::NativeMultispin => Ok(FarmEngine::Multispin),
            EngineKind::NativeBatch => Ok(FarmEngine::Batch),
            EngineKind::NativeTensor(Precision::F32) => Ok(FarmEngine::Tensor),
            // Refuse rather than silently coerce: a tensor-fp16 sweep
            // would report f32-path rates under an fp16 label.
            EngineKind::NativeTensor(Precision::F16) => Err(Error::Usage(
                "the farm runs the tensor engine's bit-exact f32 GEMM path; use \
                 --engine tensor (fp16 emulation is a single-run benchmark mode: \
                 `ising run --engine tensor-fp16`)"
                    .into(),
            )),
            other => Err(Error::Usage(format!(
                "the replica farm drives 'scalar', 'domain', 'multispin', 'batch' \
                 or 'tensor' replicas, not '{}' (run it directly: `ising run \
                 --engine {}`)",
                other.name(),
                other.name()
            ))),
        }
    }
}

/// Configuration of one farm run.
#[derive(Clone, Debug)]
pub struct FarmConfig {
    /// Lattice geometry shared by every replica.
    pub geom: Geometry,
    /// Inverse temperatures to visit (outer grid dimension).
    pub betas: Vec<f32>,
    /// Seeds per β (inner grid dimension).
    pub seeds: Vec<u32>,
    /// Slab count inside each replica's `NativeCluster`.
    pub shards: usize,
    /// Worker threads executing replicas.
    pub workers: usize,
    /// Equilibration sweeps per replica (u64: the long-run regime is the
    /// whole point of the farm).
    pub burn_in: u64,
    /// Measurement samples per replica.
    pub samples: usize,
    /// Sweeps between samples.
    pub thin: u64,
    /// Run each replica's shards on threads too (off by default: the farm
    /// parallelizes across replicas; turning both on oversubscribes cores).
    pub threaded_shards: bool,
    /// Slab worker threads inside each domain-decomposed replica
    /// (`FarmEngine::Domain` only; other engines require 1). Execution
    /// layout like `workers`: excluded from the manifest fingerprint,
    /// because domain trajectories are thread-count-invariant.
    pub threads: usize,
    /// Engine family per replica (`shards`/`threaded_shards` apply to the
    /// multispin cluster only; `threads` to the domain engine only; the
    /// tensor engine is single-block).
    pub engine: FarmEngine,
}

impl FarmConfig {
    /// A ready-to-run configuration: `betas` β points × `replicas` seeds
    /// starting at `seed0`, on an `l`² lattice.
    pub fn grid(l: usize, betas: Vec<f32>, replicas: usize, seed0: u32) -> Result<Self> {
        Ok(Self {
            geom: Geometry::square(l)?,
            betas,
            seeds: (0..replicas.max(1) as u32).map(|r| seed0.wrapping_add(r)).collect(),
            shards: 1,
            workers: 1,
            burn_in: 300,
            samples: 100,
            thin: 2,
            threaded_shards: false,
            threads: 1,
            engine: FarmEngine::Multispin,
        })
    }

    /// Total replica count (β × seed grid size).
    pub fn replica_count(&self) -> usize {
        self.betas.len() * self.seeds.len()
    }

    /// Shared semantic validation — the single source of the grid and
    /// engine-compatibility rules, enforced identically by every entry
    /// point: the `ising sweep` CLI, the `/v1/jobs` API, the persisted
    /// job-spec restart scan, and the farm itself as a backstop. A new
    /// engine's rules live here once and cannot drift between entry
    /// points. Returns [`Error::Usage`] (it is always caller error).
    pub fn validate(&self) -> Result<()> {
        if self.betas.is_empty() || self.seeds.is_empty() {
            return Err(Error::Usage(
                "replica farm needs a non-empty β × seed grid".into(),
            ));
        }
        for &b in &self.betas {
            if !b.is_finite() || b <= 0.0 {
                return Err(Error::Usage(format!(
                    "β value {b} must be finite and > 0"
                )));
            }
        }
        if self.samples == 0 {
            return Err(Error::Usage("samples must be ≥ 1".into()));
        }
        if self.workers == 0 {
            return Err(Error::Usage("workers must be ≥ 1".into()));
        }
        if self.shards == 0 {
            return Err(Error::Usage("shards must be ≥ 1".into()));
        }
        if self.threads > 1 && self.engine != FarmEngine::Domain {
            return Err(Error::Usage(format!(
                "'threads' splits one lattice across slab workers, which only \
                 the domain engine does; '{}' replicas take threads = 1",
                self.engine.name()
            )));
        }
        match self.engine {
            FarmEngine::Multispin => {
                if self.geom.w % 32 != 0 {
                    return Err(Error::Usage(format!(
                        "engine 'multispin' needs lattice width % 32 == 0, got {}",
                        self.geom.w
                    )));
                }
            }
            FarmEngine::Domain => {
                if self.shards > 1 || self.threaded_shards {
                    return Err(Error::Usage(
                        "'shards'/'threaded-shards' apply to the multispin engine; \
                         'domain' replicas split across slab threads (--threads)"
                            .into(),
                    ));
                }
                crate::algorithms::domain::validate_split(self.geom.h, self.threads.max(1))?;
            }
            // Single-block replica engines: intra-replica sharding knobs
            // would be silently ignored, so they are refused.
            FarmEngine::Scalar | FarmEngine::Tensor | FarmEngine::Batch => {
                if self.shards > 1 || self.threaded_shards {
                    return Err(Error::Usage(format!(
                        "'shards'/'threaded-shards' apply to the multispin engine; \
                         '{}' replicas are single-block",
                        self.engine.name()
                    )));
                }
            }
        }
        Ok(())
    }
}

/// One replica's recorded run.
#[derive(Clone, Debug)]
pub struct ReplicaResult {
    /// Inverse temperature of this replica.
    pub beta: f32,
    /// Seed of this replica.
    pub seed: u32,
    /// Per-sample magnetization per site (signed).
    pub m_series: Vec<f64>,
    /// Per-sample energy per site.
    pub e_series: Vec<f64>,
    /// Throughput accounting of this replica's cluster.
    pub metrics: Metrics,
}

impl ReplicaResult {
    /// ⟨|m|⟩ over the recorded samples.
    pub fn mean_abs_m(&self) -> f64 {
        stats::mean_abs(&self.m_series)
    }

    /// Blocked error on |m| (naive fallback below 8 samples).
    pub fn err_abs_m(&self) -> f64 {
        stats::stderr_blocked_abs(&self.m_series)
    }

    /// ⟨e⟩ over the recorded samples.
    pub fn mean_e(&self) -> f64 {
        stats::mean(&self.e_series)
    }

    /// Binder accumulator over the recorded magnetizations.
    pub fn binder(&self) -> BinderAccumulator {
        let mut acc = BinderAccumulator::new();
        for &m in &self.m_series {
            acc.push(m);
        }
        acc
    }

    /// This replica's sweep throughput.
    pub fn flips_per_ns(&self) -> f64 {
        self.metrics.flips_per_ns()
    }
}

/// Aggregated outcome of a farm run.
#[derive(Clone, Debug)]
pub struct FarmResult {
    /// Per-replica results in deterministic (β-major, then seed) order.
    pub replicas: Vec<ReplicaResult>,
    /// Wall-clock time of the whole farm.
    pub wall: Duration,
    /// Worker threads used.
    pub workers: usize,
    /// Merged metrics across replicas (`elapsed` is summed CPU sweep time).
    pub aggregate: Metrics,
}

impl FarmResult {
    /// Aggregate throughput against *wall clock* — the number that should
    /// scale near-linearly with `workers` on idle cores.
    pub fn flips_per_ns_wall(&self) -> f64 {
        crate::util::units::flips_per_ns(self.aggregate.flips, self.wall.as_secs_f64())
    }

    /// Parallel efficiency: summed in-replica sweep time divided by
    /// `workers × wall` (1.0 = perfectly linear scaling).
    pub fn parallel_efficiency(&self) -> f64 {
        let wall = self.wall.as_secs_f64();
        if wall <= 0.0 || self.workers == 0 {
            return f64::NAN;
        }
        self.aggregate.elapsed.as_secs_f64() / (wall * self.workers as f64)
    }

    /// The bit-exact per-replica report: β/m/e as hex bit patterns, so
    /// two runs of the same grid can be compared with a plain `diff`
    /// (decimal formatting would hide 1-ulp divergence; wall-clock
    /// metrics are deliberately excluded). `ising sweep --report` writes
    /// exactly this string, and the job API's result endpoint serves it
    /// byte-identically — the CI smoke steps diff the two.
    pub fn replica_report(&self) -> String {
        use std::fmt::Write as _;
        let mut out = String::new();
        out.push_str(REPORT_HEADER);
        for r in &self.replicas {
            let _ = write!(out, "beta_bits={:08x} seed={} m=", r.beta.to_bits(), r.seed);
            for (i, v) in r.m_series.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                let _ = write!(out, "{:016x}", v.to_bits());
            }
            out.push_str(" e=");
            for (i, v) in r.e_series.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                let _ = write!(out, "{:016x}", v.to_bits());
            }
            out.push('\n');
        }
        out
    }

    /// Record this result into a metrics registry under an `engine`
    /// label. Everything recorded here is derived from already-measured
    /// counters and the farm's own wall duration — no clock reads, so
    /// the det-zone invariant (timing never feeds trajectory state)
    /// holds by construction.
    pub fn record_metrics(&self, reg: &crate::obs::Registry, engine: &str) {
        let labels = [("engine", engine)];
        reg.counter(
            "ising_replicas_completed_total",
            "Replicas finished across farm runs.",
            &labels,
            self.replicas.len() as f64,
        );
        reg.counter(
            "ising_flips_total",
            "Spin-flip attempts accumulated across farm runs.",
            &labels,
            self.aggregate.flips as f64,
        );
        reg.gauge(
            "ising_engine_flips_per_ns",
            "Wall-clock flips/ns of the most recent completed farm run.",
            &labels,
            self.flips_per_ns_wall(),
        );
        let eff = self.parallel_efficiency();
        if eff.is_finite() {
            reg.gauge(
                "ising_parallel_efficiency",
                "Summed replica sweep time / (workers x wall) of the last run.",
                &labels,
                eff,
            );
        }
    }

    /// Group replicas by β (grid order), pooling every seed's samples into
    /// one [`BinderAccumulator`] per β — the Fig. 6 curve points.
    pub fn by_beta(&self) -> Vec<(f32, BinderAccumulator)> {
        let mut out: Vec<(f32, BinderAccumulator)> = Vec::new();
        for r in &self.replicas {
            match out.iter_mut().find(|(b, _)| b.to_bits() == r.beta.to_bits()) {
                Some((_, acc)) => {
                    for &m in &r.m_series {
                        acc.push(m);
                    }
                }
                None => out.push((r.beta, r.binder())),
            }
        }
        out
    }
}

/// First line of every replica report ([`FarmResult::replica_report`]).
/// The fleet coordinator validates uploaded per-unit reports against
/// this exact header before splicing their lines into the merged file.
pub const REPORT_HEADER: &str =
    "# ising sweep replica report v1 (f32/f64 values as hex bit patterns)\n";

/// Outcome of a (possibly checkpointed) farm invocation.
#[derive(Debug)]
pub enum FarmOutcome {
    /// Every replica finished; full results.
    Complete(FarmResult),
    /// The sample budget ran out first; all progress is persisted in the
    /// checkpoint directory and a `resume` invocation will finish the
    /// grid bit-identically.
    Interrupted {
        /// Replicas fully done per the manifest — across *all* passes
        /// over this checkpoint dir, not just tasks claimed in this one
        /// (an exhausted budget stops workers before they even claim
        /// already-complete replicas).
        completed: usize,
        /// Total grid size.
        total: usize,
    },
}

/// Per-replica result as seen by the per-replica task body.
enum ReplicaStatus {
    Done(ReplicaResult),
    Paused,
}

/// One schedulable unit of farm work: a single replica for the
/// per-replica engine families, or up to 64 same-β replicas sharing one
/// batched bit-plane engine. `first` is the grid task index (β-major,
/// then seed) of `seeds[0]`; a unit's replicas occupy the consecutive
/// indices `first..first + seeds.len()`. This is also the unit of
/// *distribution*: the fleet coordinator leases whole WorkUnits to
/// remote workers and splices their reports back in unit order.
pub struct WorkUnit {
    /// Coupling shared by every lane of this unit.
    pub beta: f32,
    /// Per-lane RNG seeds, in grid order.
    pub seeds: Vec<u32>,
    /// Grid task index of `seeds[0]` (β-major, then seed).
    pub first: usize,
}

/// Per-unit result as seen by the farm loop.
enum UnitStatus {
    Done(Vec<ReplicaResult>),
    Paused,
}

/// Decompose the grid into work units. For the batch engine each β's
/// seeds are chunked into groups of up to [`batch::LANES`] (the
/// manifest records this layout); other engines get one unit per
/// replica. Units are emitted in grid order, so flattening unit results
/// in unit order reproduces the deterministic β-major output order —
/// locally in the farm loop and remotely in the fleet coordinator's
/// merged report alike.
pub fn work_units(cfg: &FarmConfig) -> Vec<WorkUnit> {
    let ns = cfg.seeds.len();
    let mut units = Vec::new();
    for (bi, &beta) in cfg.betas.iter().enumerate() {
        match cfg.engine {
            FarmEngine::Batch => {
                let mut off = 0usize;
                for chunk in cfg.seeds.chunks(batch::LANES) {
                    units.push(WorkUnit {
                        beta,
                        seeds: chunk.to_vec(),
                        first: bi * ns + off,
                    });
                    off += chunk.len();
                }
            }
            FarmEngine::Scalar
            | FarmEngine::Domain
            | FarmEngine::Multispin
            | FarmEngine::Tensor => {
                for (si, &seed) in cfg.seeds.iter().enumerate() {
                    units.push(WorkUnit { beta, seeds: vec![seed], first: bi * ns + si });
                }
            }
        }
    }
    units
}

/// Split the batch's cumulative metrics into one lane's share: lanes
/// advance in lockstep, so each owns an equal slice of the flips and of
/// the sweep time — summing the lane metrics over a unit reproduces the
/// batch totals (modulo integer division), and the farm aggregate's
/// flips/ns stays the real hardware throughput.
fn lane_metrics(total: &Metrics, lanes: usize) -> Metrics {
    let lanes = lanes.max(1);
    let mut m = Metrics::new();
    m.flips = total.flips / lanes as u64;
    m.sweeps = total.sweeps;
    m.elapsed = Duration::from_nanos((total.elapsed.as_nanos() / lanes as u128) as u64);
    m
}

/// One replica's simulator — the engine-family dispatch behind the farm
/// loop. Both variants expose the same protocol surface (step counter,
/// chunked runs, observables, snapshot, cumulative metrics), so
/// `run_replica` is engine-agnostic.
enum ReplicaSim {
    /// Sharded multi-spin cluster (tracks its own metrics).
    Cluster(Box<NativeCluster>),
    /// Tensor engine plus farm-side metrics accounting (boxed: the
    /// engine carries band + scratch buffers).
    Tensor(Box<TensorReplica>),
    /// Reference byte-plane engine plus farm-side metrics accounting.
    Scalar(Box<ScalarReplica>),
    /// Domain-decomposed engine (slab threads inside the replica) plus
    /// farm-side metrics accounting.
    Domain(Box<DomainReplica>),
}

struct TensorReplica {
    engine: TensorEngine,
    metrics: Metrics,
}

struct ScalarReplica {
    engine: ScalarEngine,
    metrics: Metrics,
}

struct DomainReplica {
    engine: DomainEngine,
    metrics: Metrics,
}

impl ReplicaSim {
    /// Hot-start a replica for grid task `(beta, seed)`.
    fn hot(cfg: &FarmConfig, beta: f32, seed: u32) -> Result<Self> {
        match cfg.engine {
            FarmEngine::Multispin => {
                let mut cluster =
                    NativeCluster::hot(cfg.geom, cfg.shards.max(1), beta, seed)?;
                cluster.threaded = cfg.threaded_shards;
                Ok(ReplicaSim::Cluster(Box::new(cluster)))
            }
            FarmEngine::Tensor => Ok(ReplicaSim::Tensor(Box::new(TensorReplica {
                engine: TensorEngine::with_precision(cfg.geom, beta, seed, Precision::F32),
                metrics: Metrics::new(),
            }))),
            FarmEngine::Scalar => Ok(ReplicaSim::Scalar(Box::new(ScalarReplica {
                engine: ScalarEngine::hot(cfg.geom, beta, seed),
                metrics: Metrics::new(),
            }))),
            FarmEngine::Domain => Ok(ReplicaSim::Domain(Box::new(DomainReplica {
                engine: DomainEngine::hot(cfg.geom, beta, seed, cfg.threads.max(1))?,
                metrics: Metrics::new(),
            }))),
            // Batched units never reach the per-replica body
            // (`run_unit` dispatches them to `run_batch_unit`).
            FarmEngine::Batch => Err(Error::Coordinator(
                "batch units are driven by run_batch_unit, not ReplicaSim".into(),
            )),
        }
    }

    /// Restore a replica from its checkpoint snapshot, carrying the
    /// cumulative metrics across the restart.
    fn from_snapshot(cfg: &FarmConfig, snap: &EngineSnapshot, metrics: Metrics) -> Result<Self> {
        match cfg.engine {
            FarmEngine::Multispin => {
                let mut cluster = NativeCluster::from_snapshot(snap, cfg.shards.max(1))?;
                cluster.threaded = cfg.threaded_shards;
                cluster.metrics = metrics;
                Ok(ReplicaSim::Cluster(Box::new(cluster)))
            }
            FarmEngine::Tensor => Ok(ReplicaSim::Tensor(Box::new(TensorReplica {
                engine: TensorEngine::from_snapshot(snap, Precision::F32)?,
                metrics,
            }))),
            FarmEngine::Scalar => Ok(ReplicaSim::Scalar(Box::new(ScalarReplica {
                engine: ScalarEngine::from_snapshot(snap)?,
                metrics,
            }))),
            FarmEngine::Domain => Ok(ReplicaSim::Domain(Box::new(DomainReplica {
                engine: DomainEngine::from_snapshot(snap, cfg.threads.max(1))?,
                metrics,
            }))),
            FarmEngine::Batch => Err(Error::Coordinator(
                "batch units are driven by run_batch_unit, not ReplicaSim".into(),
            )),
        }
    }

    /// Sweep counter (next sweep number).
    fn step(&self) -> u64 {
        match self {
            ReplicaSim::Cluster(c) => c.step(),
            ReplicaSim::Tensor(t) => t.engine.step,
            ReplicaSim::Scalar(s) => s.engine.step,
            ReplicaSim::Domain(d) => d.engine.step(),
        }
    }

    /// Run `n` sweeps, accounting them in the cumulative metrics.
    fn run(&mut self, n: u64) {
        match self {
            ReplicaSim::Cluster(c) => c.run(n),
            ReplicaSim::Tensor(t) => {
                let timer = Timer::start();
                t.engine.run(n);
                let sites = t.engine.lattice.geometry().sites() as u64;
                t.metrics.flips += n * sites;
                t.metrics.sweeps += n;
                t.metrics.elapsed += timer.elapsed();
            }
            ReplicaSim::Scalar(s) => {
                let timer = Timer::start();
                s.engine.sweep_n(n);
                let sites = s.engine.lattice.geometry().sites() as u64;
                s.metrics.flips += n * sites;
                s.metrics.sweeps += n;
                s.metrics.elapsed += timer.elapsed();
            }
            ReplicaSim::Domain(d) => {
                let timer = Timer::start();
                d.engine.sweep_n(n);
                let sites = d.engine.geometry().sites() as u64;
                d.metrics.flips += n * sites;
                d.metrics.sweeps += n;
                d.metrics.elapsed += timer.elapsed();
            }
        }
    }

    /// Magnetization per site.
    fn magnetization(&self) -> f64 {
        match self {
            ReplicaSim::Cluster(c) => c.lattice.magnetization(),
            ReplicaSim::Tensor(t) => t.engine.lattice.magnetization(),
            ReplicaSim::Scalar(s) => s.engine.lattice.magnetization(),
            ReplicaSim::Domain(d) => d.engine.magnetization(),
        }
    }

    /// Energy per site.
    fn energy_per_site(&self) -> f64 {
        match self {
            ReplicaSim::Cluster(c) => c.lattice.energy_per_site(),
            ReplicaSim::Tensor(t) => t.engine.lattice.energy_per_site(),
            ReplicaSim::Scalar(s) => s.engine.lattice.energy_per_site(),
            ReplicaSim::Domain(d) => d.engine.energy_per_site(),
        }
    }

    /// Checkpointable engine state.
    fn snapshot(&self) -> EngineSnapshot {
        match self {
            ReplicaSim::Cluster(c) => c.snapshot(),
            ReplicaSim::Tensor(t) => t.engine.snapshot(),
            ReplicaSim::Scalar(s) => s.engine.snapshot(),
            ReplicaSim::Domain(d) => d.engine.snapshot(),
        }
    }

    /// Cumulative metrics.
    fn metrics(&self) -> &Metrics {
        match self {
            ReplicaSim::Cluster(c) => &c.metrics,
            ReplicaSim::Tensor(t) => &t.metrics,
            ReplicaSim::Scalar(s) => &s.metrics,
            ReplicaSim::Domain(d) => &d.metrics,
        }
    }

    /// Consume into the cumulative metrics (final result assembly).
    fn into_metrics(self) -> Metrics {
        match self {
            ReplicaSim::Cluster(c) => c.metrics,
            ReplicaSim::Tensor(t) => t.metrics,
            ReplicaSim::Scalar(s) => s.metrics,
            ReplicaSim::Domain(d) => d.metrics,
        }
    }
}

/// Run one replica (the per-task body of the farm), resuming from and
/// writing checkpoints when a [`Checkpointer`] is present.
fn run_replica(
    cfg: &FarmConfig,
    beta: f32,
    seed: u32,
    idx: usize,
    ckpt: Option<&Checkpointer>,
) -> Result<ReplicaStatus> {
    let thin = cfg.thin.max(1);
    let restored = match ckpt {
        Some(c) => c.load_replica(idx, cfg, beta, seed)?,
        None => None,
    };
    let (mut sim, mut m_series, mut e_series) = match restored {
        Some(p) => {
            let sim = ReplicaSim::from_snapshot(cfg, &p.engine, p.metrics)?;
            (sim, p.m_series, p.e_series)
        }
        None => (
            ReplicaSim::hot(cfg, beta, seed)?,
            Vec::with_capacity(cfg.samples),
            Vec::with_capacity(cfg.samples),
        ),
    };

    // Burn-in — chunked so long equilibrations checkpoint too.
    while sim.step() < cfg.burn_in {
        match ckpt {
            Some(c) => {
                if c.budget_exhausted() {
                    c.save_replica(idx, sim.snapshot(), sim.metrics(), &m_series, &e_series)?;
                    return Ok(ReplicaStatus::Paused);
                }
                let chunk =
                    (c.every() as u64 * thin).max(1).min(cfg.burn_in - sim.step());
                sim.run(chunk);
                c.save_replica(idx, sim.snapshot(), sim.metrics(), &m_series, &e_series)?;
            }
            None => sim.run(cfg.burn_in - sim.step()),
        }
    }

    // Sampling (resumes mid-series: the sweep counter already sits at
    // `burn_in + len * thin`, so the continuation is bit-identical).
    while m_series.len() < cfg.samples {
        if let Some(c) = ckpt {
            if !c.take_sample() {
                c.save_replica(idx, sim.snapshot(), sim.metrics(), &m_series, &e_series)?;
                return Ok(ReplicaStatus::Paused);
            }
        }
        sim.run(thin);
        m_series.push(sim.magnetization());
        e_series.push(sim.energy_per_site());
        if let Some(c) = ckpt {
            if c.due(m_series.len()) || m_series.len() == cfg.samples {
                c.save_replica(idx, sim.snapshot(), sim.metrics(), &m_series, &e_series)?;
            }
        }
    }
    if let Some(c) = ckpt {
        c.mark_done(idx)?;
    }
    Ok(ReplicaStatus::Done(ReplicaResult {
        beta,
        seed,
        m_series,
        e_series,
        metrics: sim.into_metrics(),
    }))
}

/// Run one batched unit: up to 64 same-β replicas advanced in lockstep
/// by a single [`BatchEngine`]. Per-lane observables are extracted at
/// every sample point (bit-transpose popcounts); the whole group
/// checkpoints as one `KIND_BATCH` file under its first task index, and
/// every lane resumes from it bit-identically. One sample-budget token
/// is claimed per sample *round* — a round yields one new sample in
/// each of the unit's lanes.
fn run_batch_unit(
    cfg: &FarmConfig,
    unit: &WorkUnit,
    ckpt: Option<&Checkpointer>,
) -> Result<UnitStatus> {
    let thin = cfg.thin.max(1);
    let lanes = unit.seeds.len();
    let restored = match ckpt {
        Some(c) => c.load_batch(unit.first, cfg, unit.beta, &unit.seeds)?,
        None => None,
    };
    let (mut engine, mut metrics, mut m_lanes, mut e_lanes) = match restored {
        Some(p) => (
            BatchEngine::from_snapshot(&p.engine)?,
            p.metrics,
            p.m_lanes,
            p.e_lanes,
        ),
        None => (
            BatchEngine::hot(cfg.geom, unit.beta, &unit.seeds)?,
            Metrics::new(),
            vec![Vec::with_capacity(cfg.samples); lanes],
            vec![Vec::with_capacity(cfg.samples); lanes],
        ),
    };
    let sites = cfg.geom.sites() as u64;
    // Advance all lanes `n` sweeps, accounting every lane's flips.
    let advance = |engine: &mut BatchEngine, metrics: &mut Metrics, n: u64| {
        let timer = Timer::start();
        engine.run(n);
        metrics.flips += n * sites * lanes as u64;
        metrics.sweeps += n;
        metrics.elapsed += timer.elapsed();
    };

    // Burn-in — chunked so long equilibrations checkpoint too.
    while engine.step < cfg.burn_in {
        match ckpt {
            Some(c) => {
                if c.budget_exhausted() {
                    c.save_batch(unit.first, engine.snapshot(), &metrics, &m_lanes, &e_lanes)?;
                    return Ok(UnitStatus::Paused);
                }
                let chunk =
                    (c.every() as u64 * thin).max(1).min(cfg.burn_in - engine.step);
                advance(&mut engine, &mut metrics, chunk);
                c.save_batch(unit.first, engine.snapshot(), &metrics, &m_lanes, &e_lanes)?;
            }
            None => advance(&mut engine, &mut metrics, cfg.burn_in - engine.step),
        }
    }

    // Sampling (resumes mid-series exactly like the per-replica path).
    while m_lanes[0].len() < cfg.samples {
        if let Some(c) = ckpt {
            if !c.take_sample() {
                c.save_batch(unit.first, engine.snapshot(), &metrics, &m_lanes, &e_lanes)?;
                return Ok(UnitStatus::Paused);
            }
        }
        advance(&mut engine, &mut metrics, thin);
        let ms = engine.lane_magnetizations();
        let es = engine.lane_energies();
        for l in 0..lanes {
            m_lanes[l].push(ms[l]);
            e_lanes[l].push(es[l]);
        }
        if let Some(c) = ckpt {
            let done = m_lanes[0].len();
            if c.due(done) || done == cfg.samples {
                c.save_batch(unit.first, engine.snapshot(), &metrics, &m_lanes, &e_lanes)?;
            }
        }
    }
    if let Some(c) = ckpt {
        c.mark_done_range(unit.first, lanes)?;
    }
    let results = unit
        .seeds
        .iter()
        .enumerate()
        .map(|(l, &seed)| ReplicaResult {
            beta: unit.beta,
            seed,
            m_series: std::mem::take(&mut m_lanes[l]),
            e_series: std::mem::take(&mut e_lanes[l]),
            metrics: lane_metrics(&metrics, lanes),
        })
        .collect();
    Ok(UnitStatus::Done(results))
}

/// Engine-family dispatch for one work unit.
fn run_unit(cfg: &FarmConfig, unit: &WorkUnit, ckpt: Option<&Checkpointer>) -> Result<UnitStatus> {
    match cfg.engine {
        FarmEngine::Batch => run_batch_unit(cfg, unit, ckpt),
        FarmEngine::Scalar
        | FarmEngine::Domain
        | FarmEngine::Multispin
        | FarmEngine::Tensor => {
            match run_replica(cfg, unit.beta, unit.seeds[0], unit.first, ckpt)? {
                ReplicaStatus::Done(r) => Ok(UnitStatus::Done(vec![r])),
                ReplicaStatus::Paused => Ok(UnitStatus::Paused),
            }
        }
    }
}

/// Execute the full β × seed grid across `cfg.workers` scoped threads,
/// optionally checkpointing into (and resuming from) a directory.
///
/// Work is pulled from a shared atomic cursor (replicas can have very
/// different equilibration costs across β, so static striping would load
/// imbalance); results land in per-task slots, so the output order is the
/// deterministic grid order regardless of completion order. With a
/// [`CheckpointSpec`], replicas resume from their snapshots and an
/// exhausted sample budget yields [`FarmOutcome::Interrupted`] with all
/// progress on disk.
pub fn run_farm_checkpointed(
    cfg: &FarmConfig,
    spec: Option<&CheckpointSpec>,
) -> Result<FarmOutcome> {
    // Shared semantic validation (CLI and job API call it too; this is
    // the backstop for library callers).
    cfg.validate()?;
    let total = cfg.replica_count();
    // Units: one replica each, or ≤ 64 same-β replicas per batch group.
    let units = work_units(cfg);
    let ckpt = match spec {
        Some(s) => Some(Checkpointer::open(s, cfg)?),
        None => None,
    };
    let ckpt = ckpt.as_ref();
    let workers = cfg.workers.max(1).min(units.len());
    let timer = Timer::start();

    let cursor = AtomicUsize::new(0);
    let slots: Vec<Mutex<Option<Result<UnitStatus>>>> =
        (0..units.len()).map(|_| Mutex::new(None)).collect();
    std::thread::scope(|scope| {
        for _ in 0..workers {
            scope.spawn(|| loop {
                // Once the budget is gone, stop claiming fresh units —
                // unclaimed replicas simply stay pending for the resume.
                if ckpt.map(|c| c.budget_exhausted()).unwrap_or(false) {
                    break;
                }
                let i = cursor.fetch_add(1, Ordering::Relaxed);
                if i >= units.len() {
                    break;
                }
                let result = run_unit(cfg, &units[i], ckpt);
                *slots[i].lock().expect("farm slot poisoned") = Some(result);
            });
        }
    });

    let wall = timer.elapsed();
    let mut replicas = Vec::with_capacity(total);
    let mut pending = 0usize;
    for slot in slots {
        match slot.into_inner().expect("farm slot poisoned") {
            // Units are in grid order and their replicas are consecutive,
            // so flattening preserves the deterministic β-major order.
            Some(Ok(UnitStatus::Done(rs))) => replicas.extend(rs),
            Some(Ok(UnitStatus::Paused)) | None => pending += 1,
            Some(Err(e)) => return Err(e),
        }
    }
    if pending > 0 {
        // Report completion from the manifest: replicas finished in
        // earlier passes stay unclaimed once the budget is exhausted, so
        // counting this invocation's slots would undercount.
        return Ok(FarmOutcome::Interrupted {
            completed: ckpt.map(|c| c.done_count()).unwrap_or(replicas.len()),
            total,
        });
    }
    let mut aggregate = Metrics::new();
    for r in &replicas {
        aggregate.merge(&r.metrics);
    }
    Ok(FarmOutcome::Complete(FarmResult { replicas, wall, workers, aggregate }))
}

/// Execute the full β × seed grid with no checkpointing (always runs to
/// completion or error).
pub fn run_farm(cfg: &FarmConfig) -> Result<FarmResult> {
    match run_farm_checkpointed(cfg, None)? {
        FarmOutcome::Complete(r) => Ok(r),
        FarmOutcome::Interrupted { .. } => Err(Error::Coordinator(
            "farm interrupted without a sample budget (unreachable)".into(),
        )),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small_cfg() -> FarmConfig {
        FarmConfig {
            geom: Geometry::new(8, 32).unwrap(),
            betas: vec![0.40, BETA_C],
            seeds: vec![1, 2],
            shards: 2,
            workers: 2,
            burn_in: 3,
            samples: 4,
            thin: 1,
            threaded_shards: false,
            threads: 1,
            engine: FarmEngine::Multispin,
        }
    }

    #[test]
    fn grid_order_and_sample_counts() {
        let cfg = small_cfg();
        let res = run_farm(&cfg).unwrap();
        assert_eq!(res.replicas.len(), 4);
        // β-major, then seed.
        let order: Vec<(u32, u32)> =
            res.replicas.iter().map(|r| (r.beta.to_bits(), r.seed)).collect();
        assert_eq!(
            order,
            vec![
                (0.40f32.to_bits(), 1),
                (0.40f32.to_bits(), 2),
                (BETA_C.to_bits(), 1),
                (BETA_C.to_bits(), 2),
            ]
        );
        for r in &res.replicas {
            assert_eq!(r.m_series.len(), 4);
            assert_eq!(r.e_series.len(), 4);
            // burn_in + samples × thin sweeps accounted.
            assert_eq!(r.metrics.sweeps, 3 + 4);
        }
        assert_eq!(
            res.aggregate.flips,
            4 * 7 * cfg.geom.sites() as u64,
            "4 replicas × 7 sweeps × sites"
        );
        assert!(res.parallel_efficiency() > 0.0);
    }

    #[test]
    fn by_beta_pools_seeds() {
        let res = run_farm(&small_cfg()).unwrap();
        let grouped = res.by_beta();
        assert_eq!(grouped.len(), 2);
        for (_, acc) in &grouped {
            assert_eq!(acc.count(), 8, "2 seeds × 4 samples pooled");
        }
    }

    #[test]
    fn empty_grid_is_an_error() {
        let mut cfg = small_cfg();
        cfg.betas.clear();
        assert!(run_farm(&cfg).is_err());
    }

    #[test]
    fn bad_shard_count_surfaces_the_cluster_error() {
        let mut cfg = small_cfg();
        cfg.shards = 3; // 8 rows % 3 != 0
        assert!(run_farm(&cfg).is_err());
    }

    /// Engine-family cross-check: the tensor farm reproduces the
    /// multispin farm's observable series bit-exactly (both follow the
    /// shared Philox site-group trajectory), and metrics account the
    /// same sweep counts.
    #[test]
    fn tensor_farm_matches_multispin_farm_bit_exactly() {
        let multispin = run_farm(&small_cfg()).unwrap();
        let mut cfg = small_cfg();
        cfg.engine = FarmEngine::Tensor;
        cfg.shards = 1;
        let tensor = run_farm(&cfg).unwrap();
        assert_eq!(tensor.replicas.len(), multispin.replicas.len());
        for (a, b) in multispin.replicas.iter().zip(&tensor.replicas) {
            assert_eq!(a.beta.to_bits(), b.beta.to_bits());
            assert_eq!(a.seed, b.seed);
            assert_eq!(a.m_series, b.m_series, "β = {}, seed = {}", a.beta, a.seed);
            assert_eq!(a.e_series, b.e_series);
            assert_eq!(a.metrics.sweeps, b.metrics.sweeps);
            assert_eq!(a.metrics.flips, b.metrics.flips);
        }
    }

    /// Sharding knobs the tensor engine would silently ignore are
    /// rejected at the farm layer, not just by the CLI.
    #[test]
    fn tensor_farm_rejects_sharding() {
        let mut cfg = small_cfg();
        cfg.engine = FarmEngine::Tensor; // small_cfg has shards: 2
        assert!(run_farm(&cfg).is_err());
        let mut cfg = small_cfg();
        cfg.engine = FarmEngine::Tensor;
        cfg.shards = 1;
        cfg.threaded_shards = true;
        assert!(run_farm(&cfg).is_err());
    }

    #[test]
    fn farm_engine_names_are_registry_names() {
        // The manifest fingerprint names must stay in sync with the
        // canonical engine registry the CLI parses against.
        use crate::config::EngineKind;
        assert_eq!(
            EngineKind::parse(FarmEngine::Multispin.name()).unwrap(),
            EngineKind::NativeMultispin
        );
        assert_eq!(
            EngineKind::parse(FarmEngine::Tensor.name()).unwrap(),
            EngineKind::NativeTensor(Precision::F32)
        );
    }

    /// The tensor farm has no %32 width constraint — any even lattice
    /// runs (here 10×10, impossible for the packed multispin path).
    #[test]
    fn tensor_farm_runs_on_non_multispin_geometries() {
        let cfg = FarmConfig {
            geom: Geometry::new(10, 10).unwrap(),
            betas: vec![BETA_C],
            seeds: vec![1],
            shards: 1,
            workers: 1,
            burn_in: 2,
            samples: 3,
            thin: 1,
            threaded_shards: false,
            threads: 1,
            engine: FarmEngine::Tensor,
        };
        let res = run_farm(&cfg).unwrap();
        assert_eq!(res.replicas.len(), 1);
        assert_eq!(res.replicas[0].m_series.len(), 3);
        assert_eq!(res.replicas[0].metrics.sweeps, 2 + 3);
    }

    /// The domain farm reproduces the scalar farm's observable series
    /// bit-exactly at every slab thread count — the slab invariance the
    /// domain engine guarantees, end to end through the farm loop.
    #[test]
    fn domain_farm_matches_scalar_farm_at_every_thread_count() {
        let mut cfg = small_cfg();
        cfg.engine = FarmEngine::Scalar;
        cfg.shards = 1;
        let scalar = run_farm(&cfg).unwrap();
        for threads in [1, 2, 4] {
            let mut cfg = small_cfg();
            cfg.engine = FarmEngine::Domain;
            cfg.shards = 1;
            cfg.threads = threads;
            let domain = run_farm(&cfg).unwrap();
            assert_eq!(
                domain.replica_report(),
                scalar.replica_report(),
                "threads = {threads}"
            );
            for r in &domain.replicas {
                assert_eq!(r.metrics.sweeps, 3 + 4);
            }
        }
    }

    /// Domain-farm knobs: bad slab splits and foreign sharding knobs
    /// are refused by the shared validation; threads on a non-domain
    /// engine is refused too.
    #[test]
    fn domain_farm_rejects_bad_splits_and_foreign_knobs() {
        let mut cfg = small_cfg();
        cfg.engine = FarmEngine::Domain;
        cfg.shards = 1;
        cfg.threads = 3; // 8 rows % 3 != 0
        assert!(run_farm(&cfg).is_err());
        let mut cfg = small_cfg();
        cfg.engine = FarmEngine::Domain; // small_cfg has shards: 2
        assert!(cfg.validate().is_err());
        let mut cfg = small_cfg();
        cfg.threads = 2; // multispin replicas take threads = 1
        assert!(cfg.validate().is_err());
    }

    #[test]
    fn farm_engine_parse_maps_registry_names() {
        assert_eq!(FarmEngine::parse("scalar").unwrap(), FarmEngine::Scalar);
        assert_eq!(FarmEngine::parse("native-scalar").unwrap(), FarmEngine::Scalar);
        assert_eq!(FarmEngine::parse("domain").unwrap(), FarmEngine::Domain);
        assert_eq!(FarmEngine::parse("slab").unwrap(), FarmEngine::Domain);
        assert_eq!(FarmEngine::parse("multispin").unwrap(), FarmEngine::Multispin);
        assert_eq!(FarmEngine::parse("optimized").unwrap(), FarmEngine::Multispin);
        assert_eq!(FarmEngine::parse("batch").unwrap(), FarmEngine::Batch);
        assert_eq!(FarmEngine::parse("batch64").unwrap(), FarmEngine::Batch);
        assert_eq!(FarmEngine::parse("multispin-batch").unwrap(), FarmEngine::Batch);
        assert_eq!(FarmEngine::parse("tensor").unwrap(), FarmEngine::Tensor);
        assert_eq!(FarmEngine::parse("tensor-fp32").unwrap(), FarmEngine::Tensor);
        // fp16 is refused (would mislabel f32-path rates), as are
        // non-farm engines and unknown names.
        assert!(FarmEngine::parse("tensor-fp16").is_err());
        assert!(FarmEngine::parse("wolff").is_err());
        assert!(FarmEngine::parse("no-such-engine").is_err());
    }

    fn batch_cfg() -> FarmConfig {
        FarmConfig {
            geom: Geometry::new(6, 10).unwrap(),
            betas: vec![0.40, BETA_C],
            seeds: vec![1, 2, 3],
            shards: 1,
            workers: 2,
            burn_in: 3,
            samples: 4,
            thin: 1,
            threaded_shards: false,
            threads: 1,
            engine: FarmEngine::Batch,
        }
    }

    /// The batch farm produces one result per grid replica, in the same
    /// deterministic β-major order as the per-replica engines, and each
    /// lane's series equals its scalar reference (lane init seed +
    /// shared stream seed) — the Block et al. convention end to end.
    #[test]
    fn batch_farm_matches_per_lane_scalar_references() {
        use crate::algorithms::{metropolis, AcceptanceTable};
        use crate::lattice::init;
        let cfg = batch_cfg();
        let res = run_farm(&cfg).unwrap();
        assert_eq!(res.replicas.len(), 6);
        let order: Vec<(u32, u32)> =
            res.replicas.iter().map(|r| (r.beta.to_bits(), r.seed)).collect();
        assert_eq!(
            order,
            vec![
                (0.40f32.to_bits(), 1),
                (0.40f32.to_bits(), 2),
                (0.40f32.to_bits(), 3),
                (BETA_C.to_bits(), 1),
                (BETA_C.to_bits(), 2),
                (BETA_C.to_bits(), 3),
            ]
        );
        // Scalar reference per lane: init from the lane seed, stream
        // from the group's first seed.
        for r in &res.replicas {
            let table = AcceptanceTable::new(r.beta);
            let stream = cfg.seeds[0];
            let mut lat = init::hot(cfg.geom, r.seed);
            let mut step = 0u64;
            // burn_in sweeps, then thin sweeps per sample.
            step = metropolis::run(&mut lat, &table, stream, step, cfg.burn_in);
            for (s, (&m, &e)) in r.m_series.iter().zip(&r.e_series).enumerate() {
                step = metropolis::run(&mut lat, &table, stream, step, cfg.thin);
                assert_eq!(m.to_bits(), lat.magnetization().to_bits(), "sample {s}");
                assert_eq!(e.to_bits(), lat.energy_per_site().to_bits(), "sample {s}");
            }
            assert_eq!(r.m_series.len(), cfg.samples);
            assert_eq!(r.metrics.sweeps, cfg.burn_in + cfg.samples as u64 * cfg.thin);
        }
        // Per-lane flips sum back to the true batch totals.
        assert_eq!(
            res.aggregate.flips,
            6 * 7 * cfg.geom.sites() as u64,
            "6 replicas × 7 sweeps × sites"
        );
    }

    /// More seeds than lanes: the farm splits each β into multiple
    /// batch groups (65 seeds → a 64-lane group + a 1-lane group), and
    /// each group's stream seed is its own first lane.
    #[test]
    fn batch_farm_splits_oversized_seed_grids() {
        use crate::algorithms::batch::LANES;
        let mut cfg = batch_cfg();
        cfg.geom = Geometry::new(4, 6).unwrap();
        cfg.betas = vec![BETA_C];
        cfg.seeds = (0..(LANES as u32 + 1)).map(|r| 10 + r).collect();
        cfg.burn_in = 1;
        cfg.samples = 2;
        let res = run_farm(&cfg).unwrap();
        assert_eq!(res.replicas.len(), LANES + 1);
        for (i, r) in res.replicas.iter().enumerate() {
            assert_eq!(r.seed, 10 + i as u32);
            assert_eq!(r.m_series.len(), 2);
        }
        // The trailing single-lane group is keyed by its own seed: it
        // must equal an ordinary scalar run of that seed.
        use crate::algorithms::{metropolis, AcceptanceTable};
        use crate::lattice::init;
        let last = res.replicas.last().unwrap();
        let table = AcceptanceTable::new(last.beta);
        let mut lat = init::hot(cfg.geom, last.seed);
        let mut step = metropolis::run(&mut lat, &table, last.seed, 0, cfg.burn_in);
        for &m in &last.m_series {
            step = metropolis::run(&mut lat, &table, last.seed, step, cfg.thin);
            assert_eq!(m.to_bits(), lat.magnetization().to_bits());
        }
    }

    /// Sharding knobs the batch engine would silently ignore are
    /// rejected by the shared validation, exactly like the tensor farm.
    #[test]
    fn batch_farm_rejects_sharding() {
        let mut cfg = batch_cfg();
        cfg.shards = 2;
        assert!(run_farm(&cfg).is_err());
        let mut cfg = batch_cfg();
        cfg.threaded_shards = true;
        assert!(run_farm(&cfg).is_err());
    }

    /// The shared validation rejects what every entry point must reject.
    #[test]
    fn farm_config_validate_is_the_shared_rulebook() {
        assert!(small_cfg().validate().is_ok());
        assert!(batch_cfg().validate().is_ok());
        let mut c = small_cfg();
        c.betas.clear();
        assert!(c.validate().is_err());
        let mut c = small_cfg();
        c.betas[0] = f32::NAN;
        assert!(c.validate().is_err());
        let mut c = small_cfg();
        c.samples = 0;
        assert!(c.validate().is_err());
        let mut c = small_cfg();
        c.workers = 0;
        assert!(c.validate().is_err());
        let mut c = small_cfg();
        c.shards = 0;
        assert!(c.validate().is_err());
        // Multispin width alignment lives here too.
        let mut c = small_cfg();
        c.geom = Geometry::new(8, 48).unwrap();
        assert!(c.validate().is_err());
        // The batch farm has no %32 width constraint (10×10 runs).
        let mut c = batch_cfg();
        c.geom = Geometry::new(10, 10).unwrap();
        assert!(c.validate().is_ok());
    }

    #[test]
    fn replica_report_is_bit_exact_and_stable() {
        let res = run_farm(&small_cfg()).unwrap();
        let report = res.replica_report();
        assert!(report.starts_with("# ising sweep replica report v1"));
        // One line per replica plus the header.
        assert_eq!(report.lines().count(), 1 + res.replicas.len());
        // Bit patterns round-trip: the first replica's first m sample.
        let line = report.lines().nth(1).unwrap();
        let m_hex = line.split("m=").nth(1).unwrap().split(',').next().unwrap();
        let bits = u64::from_str_radix(m_hex, 16).unwrap();
        assert_eq!(f64::from_bits(bits), res.replicas[0].m_series[0]);
        // Deterministic: a second identical farm produces the same bytes.
        let again = run_farm(&small_cfg()).unwrap();
        assert_eq!(again.replica_report(), report);
    }

    #[test]
    fn default_grid_brackets_beta_c() {
        let g = default_beta_grid(5);
        assert_eq!(g.len(), 5);
        assert!(g[0] < BETA_C && BETA_C < g[4]);
        assert!(g.windows(2).all(|w| w[0] < w[1]));
        assert_eq!(default_beta_grid(1), vec![BETA_C]);
    }
}
