//! Multi-device drivers.
//!
//! Two concrete coordinators reproduce the paper's §4:
//!
//! * [`SlabCluster`] — the PJRT path: one *virtual device* per slab, each
//!   stepped by the AOT-compiled `slab_*` programs; the coordinator plays
//!   the role of the unified-memory system, shipping boundary rows
//!   between devices at every color phase (the NVLink page reads of
//!   Fig. 4). Dispatch is sequential (single CPU core, `xla` types are
//!   !Send); *timing* of a true parallel system comes from
//!   `perfmodel`, while correctness is bit-exact against single-device.
//!
//! * [`NativeCluster`] — the optimized path: the packed multi-spin
//!   lattice updated by worker threads over disjoint row ranges, reading
//!   neighbor rows directly from the shared source plane exactly as the
//!   paper's GPUs read remote slabs through NVLink.

use super::metrics::Metrics;
use super::partition::{partition, Slab};
use crate::algorithms::acceptance::AcceptanceTable;
use crate::algorithms::multispin;
use crate::error::Result;
use crate::lattice::{Color, Geometry, PackedLattice};
use crate::util::timer::Timer;
#[cfg(feature = "pjrt")]
use crate::error::Error;
#[cfg(feature = "pjrt")]
use crate::lattice::Checkerboard;
#[cfg(feature = "pjrt")]
use crate::runtime::{buffers, Engine, Program, ProgramKind, Variant};
#[cfg(feature = "pjrt")]
use std::rc::Rc;

/// Per-device state of the PJRT slab cluster.
#[cfg(feature = "pjrt")]
struct SlabDevice {
    slab: Slab,
    /// (height, w2) color planes, host-resident between dispatches.
    planes: [Vec<i8>; 2],
    /// Slab programs for (black, white) phases.
    progs: [Program; 2],
}

/// PJRT multi-device coordinator (basic / tensorcore variants).
#[cfg(feature = "pjrt")]
pub struct SlabCluster {
    geom: Geometry,
    devices: Vec<SlabDevice>,
    beta: f32,
    seed: u32,
    step: u32,
    /// Throughput accounting.
    pub metrics: Metrics,
}

#[cfg(feature = "pjrt")]
impl SlabCluster {
    /// Build a hot-started cluster of `n` virtual devices.
    pub fn hot(
        engine: Rc<Engine>,
        variant: Variant,
        geom: Geometry,
        n: usize,
        beta: f32,
        seed: u32,
    ) -> Result<Self> {
        if variant == Variant::Multispin {
            return Err(Error::Coordinator(
                "multispin uses NativeCluster (packed planes)".into(),
            ));
        }
        let slabs = partition(geom, n)?;
        let full = crate::lattice::init::hot(geom, seed);
        let w2 = geom.w2();
        let mut devices = Vec::with_capacity(n);
        for slab in slabs {
            let rows = slab.base_row * w2..(slab.base_row + slab.height) * w2;
            let planes = [
                full.plane(Color::Black)[rows.clone()].to_vec(),
                full.plane(Color::White)[rows.clone()].to_vec(),
            ];
            let progs = [
                engine.load(ProgramKind::Slab, variant, slab.height, geom.w, Some(Color::Black))?,
                engine.load(ProgramKind::Slab, variant, slab.height, geom.w, Some(Color::White))?,
            ];
            devices.push(SlabDevice { slab, planes, progs });
        }
        Ok(Self { geom, devices, beta, seed, step: 0, metrics: Metrics::new() })
    }

    /// One full sweep: two color phases with halo exchange in between —
    /// the exact structure of the paper's two kernel launches per step.
    pub fn sweep(&mut self) -> Result<()> {
        let timer = Timer::start();
        let w2 = self.geom.w2();
        let n = self.devices.len();
        for color in Color::BOTH {
            let c = color.index();
            let s = color.other().index();
            // Halo gather: device i needs the source plane's last row of
            // device i-1 and first row of device i+1 (periodic).
            let tops: Vec<Vec<i8>> = (0..n)
                .map(|i| {
                    let src = &self.devices[(i + n - 1) % n].planes[s];
                    src[src.len() - w2..].to_vec()
                })
                .collect();
            let bots: Vec<Vec<i8>> = (0..n)
                .map(|i| self.devices[(i + 1) % n].planes[s][..w2].to_vec())
                .collect();
            for (i, dev) in self.devices.iter_mut().enumerate() {
                let h = dev.slab.height;
                let out = dev.progs[c].run(&[
                    buffers::plane_i8(&dev.planes[c], h, w2)?,
                    buffers::plane_i8(&dev.planes[s], h, w2)?,
                    buffers::plane_i8(&tops[i], 1, w2)?,
                    buffers::plane_i8(&bots[i], 1, w2)?,
                    buffers::scalar_f32(self.beta),
                    buffers::scalar_u32(self.seed),
                    buffers::scalar_u32(self.step),
                    buffers::scalar_u32(dev.slab.base_row as u32),
                ])?;
                dev.planes[c] = buffers::read_i8(&out[0])?;
            }
        }
        self.step += 1;
        self.metrics.record_sweep(self.geom.sites() as u64, timer.elapsed());
        Ok(())
    }

    /// Run `n` sweeps.
    pub fn run(&mut self, n: u32) -> Result<()> {
        for _ in 0..n {
            self.sweep()?;
        }
        Ok(())
    }

    /// Reassemble the full lattice (validation / observables).
    pub fn gather(&self) -> Checkerboard {
        let mut full = Checkerboard::cold(self.geom);
        let w2 = self.geom.w2();
        for dev in &self.devices {
            let rows = dev.slab.base_row * w2..(dev.slab.base_row + dev.slab.height) * w2;
            full.plane_mut(Color::Black)[rows.clone()].copy_from_slice(&dev.planes[0]);
            full.plane_mut(Color::White)[rows].copy_from_slice(&dev.planes[1]);
        }
        full
    }

    /// Number of devices.
    pub fn device_count(&self) -> usize {
        self.devices.len()
    }

    /// Sweep counter.
    pub fn step(&self) -> u32 {
        self.step
    }
}

/// Native multi-worker coordinator over the packed multi-spin lattice.
///
/// Workers update disjoint row ranges of the target plane while reading
/// the full source plane — the in-process mirror of NVLink remote reads.
/// Worker count beyond the core count still exercises the partitioning
/// logic (correctness is partition-invariant by construction).
pub struct NativeCluster {
    /// The shared lattice.
    pub lattice: PackedLattice,
    slabs: Vec<Slab>,
    table: AcceptanceTable,
    seed: u32,
    /// Next sweep number — u64 so week-long runs never wrap; the low 32
    /// bits feed the Philox counter lane.
    step: u64,
    /// Throughput accounting.
    pub metrics: Metrics,
    /// Use threads (true) or sequential dispatch (false, deterministic
    /// profiling mode).
    pub threaded: bool,
}

impl NativeCluster {
    /// Hot-started native cluster.
    pub fn hot(geom: Geometry, n: usize, beta: f32, seed: u32) -> Result<Self> {
        let slabs = partition(geom, n)?;
        Ok(Self {
            lattice: crate::lattice::init::hot_packed(geom, seed)?,
            slabs,
            table: AcceptanceTable::new(beta),
            seed,
            step: 0,
            metrics: Metrics::new(),
            threaded: true,
        })
    }

    /// Full cluster state as a checkpointable snapshot. The slab count is
    /// *not* recorded: trajectories are partition-invariant, so a snapshot
    /// may be restored under any shard layout (even a different worker
    /// topology) and still continue bit-identically.
    pub fn snapshot(&self) -> crate::util::snapshot::EngineSnapshot {
        crate::util::snapshot::EngineSnapshot::from_packed(
            &self.lattice,
            self.table.beta,
            self.seed,
            self.step,
        )
    }

    /// Rebuild a cluster from a snapshot with `n` slabs. Metrics start
    /// fresh — cumulative accounting across restarts is the farm
    /// checkpoint layer's job.
    pub fn from_snapshot(
        snap: &crate::util::snapshot::EngineSnapshot,
        n: usize,
    ) -> Result<Self> {
        let geom = snap.geometry()?;
        Ok(Self {
            lattice: snap.to_packed()?,
            slabs: partition(geom, n)?,
            table: AcceptanceTable::new(snap.beta()),
            seed: snap.seed,
            step: snap.step,
            metrics: Metrics::new(),
            threaded: true,
        })
    }

    /// Save the cluster state to a snapshot file.
    pub fn save(&self, path: &std::path::Path) -> Result<()> {
        self.snapshot().save(path)
    }

    /// Load a cluster from a snapshot file with `n` slabs.
    pub fn load(path: &std::path::Path, n: usize) -> Result<Self> {
        Self::from_snapshot(&crate::util::snapshot::EngineSnapshot::load(path)?, n)
    }

    /// Sweep counter (next sweep number).
    pub fn step(&self) -> u64 {
        self.step
    }

    /// Inverse temperature.
    pub fn beta(&self) -> f32 {
        self.table.beta
    }

    /// Philox seed.
    pub fn seed(&self) -> u32 {
        self.seed
    }

    /// One full sweep (two color phases, barrier between).
    pub fn sweep(&mut self) {
        let timer = Timer::start();
        let geom = self.lattice.geometry();
        let (h, wpr) = (geom.h, self.lattice.wpr());
        for color in Color::BOTH {
            let (target, source) = self.lattice.split_planes(color);
            if self.threaded && self.slabs.len() > 1 {
                // Split the target plane into per-slab row chunks; the
                // source plane is shared read-only (the "NVLink" reads).
                let mut chunks: Vec<&mut [u64]> = Vec::with_capacity(self.slabs.len());
                let mut rest = target;
                for slab in &self.slabs {
                    let (head, tail) = rest.split_at_mut(slab.height * wpr);
                    chunks.push(head);
                    rest = tail;
                }
                let table = &self.table;
                let (seed, step) = (self.seed, self.step as u32);
                std::thread::scope(|scope| {
                    for (slab, chunk) in self.slabs.iter().zip(chunks) {
                        let src = &*source;
                        scope.spawn(move || {
                            // Worker updates its chunk over *global* rows;
                            // vertical neighbors outside the chunk are read
                            // from the shared full source plane — the
                            // in-process NVLink.
                            multispin::update_color_rows(
                                chunk,
                                slab.base_row,
                                src,
                                h,
                                wpr,
                                slab.base_row..slab.base_row + slab.height,
                                color,
                                table,
                                seed,
                                step,
                            );
                        });
                    }
                });
            } else {
                for slab in &self.slabs {
                    multispin::update_color_rows(
                        target,
                        0,
                        source,
                        h,
                        wpr,
                        slab.base_row..slab.base_row + slab.height,
                        color,
                        &self.table,
                        self.seed,
                        self.step as u32,
                    );
                }
            }
        }
        self.step += 1;
        self.metrics.record_sweep(geom.sites() as u64, timer.elapsed());
    }

    /// Run `n` sweeps.
    pub fn run(&mut self, n: u64) {
        for _ in 0..n {
            self.sweep();
        }
    }

    /// Worker count.
    pub fn device_count(&self) -> usize {
        self.slabs.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// NativeCluster invariant: any worker count gives the bit-identical
    /// trajectory of the single-worker (= plain multispin) engine.
    #[test]
    fn native_cluster_partition_invariance_sequential() {
        let geom = Geometry::new(16, 64).unwrap();
        let single = crate::lattice::init::hot_packed(geom, 7).unwrap();
        let table = AcceptanceTable::new(0.43);
        for n in [1usize, 2, 4] {
            let mut cluster = NativeCluster::hot(geom, n, 0.43, 7).unwrap();
            cluster.threaded = false;
            cluster.run(5);
            let mut want = single.clone();
            for t in 0..5 {
                multispin::sweep(&mut want, &table, 7, t);
            }
            assert_eq!(cluster.lattice, want, "n = {n}");
        }
    }

    #[test]
    fn native_cluster_snapshot_resumes_under_any_partition() {
        // Snapshot at sweep 4 under 2 slabs, restore under 4 slabs: the
        // continuation must be bit-identical (partition invariance).
        let geom = Geometry::new(16, 64).unwrap();
        let mut a = NativeCluster::hot(geom, 2, 0.44, 11).unwrap();
        a.threaded = false;
        a.run(4);
        let snap = a.snapshot();
        assert_eq!(snap.step, 4);
        let mut b = NativeCluster::from_snapshot(&snap, 4).unwrap();
        b.threaded = false;
        assert_eq!(a.lattice, b.lattice);
        a.run(5);
        b.run(5);
        assert_eq!(a.lattice, b.lattice);
        assert_eq!(a.step(), b.step());
        assert_eq!(b.beta(), 0.44);
        assert_eq!(b.seed(), 11);
    }

    #[test]
    fn native_cluster_threaded_equals_sequential() {
        // Threaded workers write disjoint chunks with global-row indexing;
        // the result must be bit-identical to sequential dispatch.
        let geom = Geometry::new(16, 64).unwrap();
        let mut a = NativeCluster::hot(geom, 4, 0.4, 9).unwrap();
        a.threaded = false;
        let mut b = NativeCluster::hot(geom, 4, 0.4, 9).unwrap();
        b.threaded = true;
        a.run(3);
        b.run(3);
        assert_eq!(a.lattice, b.lattice);
    }
}
