//! Domain-decomposition scaling — the paper's §5 multi-GPU scaling
//! study transposed onto the CPU slab engine: one lattice split across
//! 1..8 worker threads with checkerboard-phase halo exchange.
//!
//! * **Strong scaling** (Table 4 analogue): a fixed 2^26-spin lattice
//!   (8192², the paper's single-GPU scale; 1024² in quick mode) across
//!   a growing thread count. Every row is asserted bit-identical to the
//!   scalar reference — the speedup column is only meaningful because
//!   the trajectory is provably the same one.
//! * **Weak scaling** (Table 3 analogue): a fixed slab of rows per
//!   thread, so the lattice grows with the thread count; efficiency is
//!   rate(n) / (n · rate(1)).
//!
//! The report feeds the CI perf gate: `scaling_domain/speedup/4` has a
//! baseline floor (the acceptance bar for the engine is >1.5× at 4
//! threads on the 2^26-spin lattice).

use ising_dgx::algorithms::{DomainEngine, ScalarEngine, Sweeper};
use ising_dgx::lattice::Geometry;
use ising_dgx::util::bench::{quick_mode, write_report};
use ising_dgx::util::json::{obj, Json};
use ising_dgx::util::timer::Timer;
use ising_dgx::util::{units, Table};

fn flips_per_ns(sites: u64, sweeps: u64, secs: f64) -> f64 {
    (sites * sweeps) as f64 / (secs * 1e9)
}

fn main() {
    let quick = quick_mode();
    let beta = 0.4406868f32;
    let seed = 4u32;

    // ---- strong scaling: fixed lattice, growing thread count --------
    let size = if quick { 1024 } else { 8192 };
    let sweeps: u64 = if quick { 24 } else { 16 };
    let geom = Geometry::square(size).unwrap();
    let sites = geom.sites() as u64;

    // Scalar reference: the 1-thread baseline the domain engine must
    // reproduce bit for bit (and the denominator of every speedup).
    let mut scalar = ScalarEngine::hot(geom, beta, seed);
    let timer = Timer::start();
    scalar.sweep_n(sweeps);
    let scalar_secs = timer.secs();
    let scalar_rate = flips_per_ns(sites, sweeps, scalar_secs);
    let reference = scalar.spins();

    let mut table = Table::new(&["threads", "flips/ns", "speedup", "state == scalar?"])
        .with_title(
            format!("Domain strong scaling — fixed {size}^2 lattice ({sites} spins)").as_str(),
        );
    let mut rows = Vec::new();
    for &n in &[1usize, 2, 4, 8] {
        let mut engine = DomainEngine::hot(geom, beta, seed, n).unwrap();
        let timer = Timer::start();
        engine.sweep_n(sweeps);
        let secs = timer.secs();
        let rate = flips_per_ns(sites, sweeps, secs);
        assert_eq!(
            engine.spins(),
            reference,
            "thread-count invariance violated at n = {n}"
        );
        table.row(&[
            n.to_string(),
            units::fmt_rate(rate),
            format!("{:.2}x", scalar_secs / secs),
            "yes".into(),
        ]);
        rows.push(obj(vec![
            ("workers", Json::Num(n as f64)),
            ("flips_per_ns", Json::Num(rate)),
            ("speedup", Json::Num(scalar_secs / secs)),
        ]));
    }
    table.print();
    println!(
        "shape check — strong scaling: halo traffic (4 rows/slab/sweep) is O(W) \
         against an O(H·W/threads) bulk, so speedup tracks the thread count \
         until slabs thin out (paper §5.2); scalar reference {} flips/ns.",
        units::fmt_rate(scalar_rate)
    );

    // ---- weak scaling: fixed rows per thread, lattice grows ---------
    let (slab_rows, width) = if quick { (256usize, 1024usize) } else { (2048, 8192) };
    let weak_sweeps: u64 = if quick { 16 } else { 8 };
    let mut weak_table = Table::new(&["threads", "lattice", "flips/ns", "efficiency"])
        .with_title(format!("Domain weak scaling — {slab_rows} rows/thread × {width}").as_str());
    let mut weak_rows = Vec::new();
    let mut base_rate = None;
    for &n in &[1usize, 2, 4, 8] {
        let geom = Geometry::new(slab_rows * n, width).unwrap();
        let sites = geom.sites() as u64;
        let mut engine = DomainEngine::hot(geom, beta, seed, n).unwrap();
        let timer = Timer::start();
        engine.sweep_n(weak_sweeps);
        let rate = flips_per_ns(sites, weak_sweeps, timer.secs());
        let base = *base_rate.get_or_insert(rate);
        let efficiency = rate / (n as f64 * base);
        weak_table.row(&[
            n.to_string(),
            format!("{}x{width}", slab_rows * n),
            units::fmt_rate(rate),
            format!("{:.0}%", efficiency * 100.0),
        ]);
        weak_rows.push(obj(vec![
            ("workers", Json::Num(n as f64)),
            ("flips_per_ns", Json::Num(rate)),
            ("efficiency", Json::Num(efficiency)),
        ]));
    }
    weak_table.print();
    println!(
        "shape check — weak scaling: per-thread work is constant, so aggregate \
         throughput grows with the thread count (paper §5.1/Table 3 analogue)."
    );

    let _ = write_report(
        "scaling_domain",
        &obj(vec![
            ("bench", Json::Str("scaling_domain".into())),
            ("rows", Json::Arr(rows)),
            ("weak", Json::Arr(weak_rows)),
        ]),
    );
}
