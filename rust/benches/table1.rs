//! Table 1 — single-device flips/ns of the basic and tensor-core
//! implementations vs the TPU baselines.
//!
//! Paper columns: Basic (Python) / Basic (CUDA C) / Tensor Core / TPUv3.
//! Our columns:   PJRT-basic (the Pallas kernel through PJRT — the
//! "high-level language" implementation), native scalar (the compiled
//! stencil — CUDA C analogue), PJRT-tensorcore (MXU matmul kernel).
//! Lattices are scaled from the paper's (k·128)², k ∈ {20..640} to
//! k ∈ {1..8} (CPU testbed, DESIGN.md §2); paper numbers are echoed so
//! shape comparisons (saturation with size, column ordering) are direct.

use ising_dgx::algorithms::ScalarEngine;
use ising_dgx::lattice::Geometry;
use ising_dgx::util::bench::{quick_mode, sweeper_flips_per_ns, write_report};
use ising_dgx::util::json::{obj, Json};
use ising_dgx::util::{units, Table};

/// The PJRT columns: per lattice size, (basic, tensorcore) flips/ns.
/// Compiled out (all `None`) when the `pjrt` feature is absent.
#[cfg(feature = "pjrt")]
fn pjrt_columns(sizes: &[usize], beta: f32, sweeps: u32) -> Vec<(Option<f64>, Option<f64>)> {
    use ising_dgx::runtime::{Engine, PjrtEngine, ProgramKind, Variant};
    use std::path::Path;
    use std::rc::Rc;

    let engine = Engine::new(Path::new("artifacts")).ok().map(Rc::new);
    if engine.is_none() {
        eprintln!("warning: artifacts missing — PJRT columns skipped (run `make artifacts`)");
    }
    sizes
        .iter()
        .map(|&l| {
            let geom = Geometry::square(l).unwrap();
            let rate = |variant: Variant| -> Option<f64> {
                let eng = engine.clone()?;
                eng.manifest.find(ProgramKind::Sweep, variant, l, l, None).ok()?;
                let mut e = PjrtEngine::hot(eng, variant, geom, beta, 1).ok()?;
                Some(sweeper_flips_per_ns(&mut e, sweeps))
            };
            (rate(Variant::Basic), rate(Variant::Tensorcore))
        })
        .collect()
}

#[cfg(not(feature = "pjrt"))]
fn pjrt_columns(sizes: &[usize], _beta: f32, _sweeps: u32) -> Vec<(Option<f64>, Option<f64>)> {
    eprintln!("note: built without the `pjrt` feature — PJRT columns skipped");
    vec![(None, None); sizes.len()]
}

/// Paper Table 1 (flips/ns): (k, basic_python, basic_cuda, tensorcore, tpu).
const PAPER: &[(usize, f64, f64, f64, f64)] = &[
    (20, 15.179, 48.147, 31.010, 8.1920),
    (40, 40.984, 59.606, 35.356, 9.3623),
    (80, 42.887, 64.578, 38.726, 12.336),
    (160, 43.594, 66.382, 39.152, 12.827),
    (320, 43.768, 66.787, 39.208, 12.906),
    (640, 43.535, 66.954, 38.749, 12.878),
];

fn main() {
    let quick = quick_mode();
    let sizes: Vec<usize> = if quick { vec![64, 128] } else { vec![64, 128, 256, 512, 1024] };
    let sweeps: u32 = if quick { 8 } else { 16 };
    let beta = 0.4406868f32;

    let pjrt = pjrt_columns(&sizes, beta, sweeps);

    let mut table = Table::new(&[
        "lattice", "pjrt-basic", "native scalar", "pjrt-tensorcore",
    ])
    .with_title("Table 1 (measured, this testbed) — flips/ns, single device");
    let mut rows = Vec::new();

    for (&l, &(basic, tensor)) in sizes.iter().zip(&pjrt) {
        let geom = Geometry::square(l).unwrap();
        let mut native = ScalarEngine::hot(geom, beta, 1);
        let scalar_rate = sweeper_flips_per_ns(&mut native, sweeps);

        let fmt = |v: Option<f64>| v.map(units::fmt_rate).unwrap_or_else(|| "-".into());
        table.row(&[
            units::fmt_lattice(l),
            fmt(basic),
            units::fmt_rate(scalar_rate),
            fmt(tensor),
        ]);
        rows.push(obj(vec![
            ("lattice", Json::Num(l as f64)),
            ("pjrt_basic", basic.map(Json::Num).unwrap_or(Json::Null)),
            ("native_scalar", Json::Num(scalar_rate)),
            ("pjrt_tensorcore", tensor.map(Json::Num).unwrap_or(Json::Null)),
        ]));
    }
    table.print();

    let mut paper = Table::new(&["lattice", "Basic(Py)", "Basic(CUDA)", "TensorCore", "TPUv3 core"])
        .with_title("Table 1 (paper, V100-SXM / TPUv3) — flips/ns");
    for &(k, py, cu, tc, tpu) in PAPER {
        paper.row(&[
            format!("({k}x128)^2"),
            format!("{py}"),
            format!("{cu}"),
            format!("{tc}"),
            format!("{tpu}"),
        ]);
    }
    paper.print();
    println!(
        "shape checks — paper: CUDA > Python, TensorCore < Basic, rates saturate with size;\n\
         ours: native scalar > PJRT variants (compiled stencil wins), same saturation."
    );

    let _ = write_report(
        "table1",
        &obj(vec![
            ("bench", Json::Str("table1".into())),
            ("beta", Json::Num(beta as f64)),
            ("sweeps", Json::Num(sweeps as f64)),
            ("rows", Json::Arr(rows)),
        ]),
    );
}
