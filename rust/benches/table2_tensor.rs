//! Table 2 (tensor core) — the §3.2 stencil-as-GEMM implementation in
//! both precision modes, vs the paper's published tensor-core rates.
//!
//! The paper benchmarks its Tensor Core implementation with FP16 inputs
//! (FP32 accumulate) on (k·128)² lattices; the FP16 reference column
//! below is that published data. The FP32 reference is an **estimate**
//! at 0.5× FP16: the paper attributes the FP16 advantage to operand
//! bytes halving through the MMA pipeline, so doubling the operand
//! width bounds FP32 at about half the rate (§3.2 discussion) — the
//! shape we check, not an exact endpoint.
//!
//! Here both modes run the same cache-blocked CPU SGEMM with f32
//! accumulation; the f16-emulation mode packs its operands to binary16
//! first (an identity on ±1 spins and 0/1/2 band weights, plus a cheap
//! per-phase pack pass), so the measured FP16/FP32 ratio sits near 1 —
//! a CPU cannot reproduce the bandwidth win the paper's MMA pipeline
//! gets from halving operand bytes, which is exactly the point the
//! comparison against the paper's reference rows makes. Both rows sit
//! orders of magnitude under the multi-spin engine, matching the
//! paper's ordering (tensor core < optimized multi-spin). Rates well
//! below 1 flips/ns print via `units::fmt_rate`, which keeps
//! significant digits instead of collapsing to `0.0`.

use ising_dgx::lattice::Geometry;
use ising_dgx::tensor::{Precision, TensorEngine};
use ising_dgx::util::bench::{quick_mode, sweeper_flips_per_ns, write_report};
use ising_dgx::util::json::{obj, Json};
use ising_dgx::util::{units, Table};

/// Paper tensor-core reference (flips/ns on V100-SXM), FP16 inputs:
/// (k, rate) for (k·128)² lattices.
const PAPER_TENSOR_FP16: &[(usize, f64)] = &[
    (20, 31.010),
    (40, 35.356),
    (80, 38.726),
    (160, 39.152),
    (320, 39.208),
    (640, 38.749),
];

/// FP32 / FP16 rate ratio estimate (operand bytes double — see the
/// module docs; a shape reference, not a published endpoint).
const FP32_RATIO_ESTIMATE: f64 = 0.5;

fn main() {
    let quick = quick_mode();
    let sizes: Vec<usize> = if quick { vec![64, 128] } else { vec![64, 128, 256, 512] };
    let beta = 0.4406868f32;

    let mut table = Table::new(&["lattice", "fp32 flips/ns", "fp16 flips/ns", "fp16/fp32"])
        .with_title("Table 2 (measured) — native tensor engine (stencil-as-GEMM), single core");
    let mut rows = Vec::new();
    for &l in &sizes {
        let geom = Geometry::square(l).unwrap();
        // Modest sweep counts: the GEMM path does O(L³) work per sweep.
        let sweeps = ((1 << 19) / geom.sites()).clamp(2, 32) as u32;
        let rate = |precision: Precision| -> f64 {
            let mut engine = TensorEngine::with_precision(geom, beta, 1, precision);
            sweeper_flips_per_ns(&mut engine, sweeps)
        };
        let r32 = rate(Precision::F32);
        let r16 = rate(Precision::F16);
        table.row(&[
            units::fmt_lattice(l),
            units::fmt_rate(r32),
            units::fmt_rate(r16),
            format!("{:.2}", r16 / r32.max(1e-12)),
        ]);
        rows.push(obj(vec![
            ("lattice", Json::Num(l as f64)),
            ("fp32_flips_per_ns", Json::Num(r32)),
            ("fp16_flips_per_ns", Json::Num(r16)),
        ]));
    }
    table.print();

    let mut paper = Table::new(&["lattice", "fp16 (paper)", "fp32 (est. 0.5x)"])
        .with_title("Table 2 (paper reference) — V100-SXM tensor core");
    let mut reference = Vec::new();
    for &(k, fp16) in PAPER_TENSOR_FP16 {
        paper.row(&[
            format!("({k}x128)^2"),
            format!("{fp16}"),
            units::fmt_rate(fp16 * FP32_RATIO_ESTIMATE),
        ]);
        reference.push(obj(vec![
            ("k", Json::Num(k as f64)),
            ("fp16_flips_per_ns", Json::Num(fp16)),
            ("fp32_flips_per_ns_estimate", Json::Num(fp16 * FP32_RATIO_ESTIMATE)),
        ]));
    }
    paper.print();

    println!(
        "shape checks — paper: tensor core saturates near 39 flips/ns, an order under the\n\
         417.57 multi-spin rate (Table 2); ours: the GEMM path likewise trails the native\n\
         multi-spin engine, and emulated fp16 ≈ fp32 (a CPU has no MMA pipeline, so\n\
         halving operand width buys no bandwidth — unlike the paper's FP16 rows)."
    );

    let _ = write_report(
        "table2_tensor",
        &obj(vec![
            ("bench", Json::Str("table2_tensor".into())),
            ("beta", Json::Num(beta as f64)),
            ("rows", Json::Arr(rows)),
            ("paper_reference", Json::Arr(reference)),
        ]),
    );
}
