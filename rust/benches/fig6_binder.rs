//! Figure 6 — Binder cumulant vs temperature for several lattice sizes;
//! the curves must cross at T_c (paper §5.3). Paper runs 512²–4096² with
//! 16M–1B sweeps; we run 16²–64² with 10⁴-scale sweeps (DESIGN.md §2) —
//! the crossing survives the scale-down because it is a universality
//! statement, not a precision one.

use ising_dgx::algorithms::MultispinEngine;
use ising_dgx::analytic;
use ising_dgx::lattice::Geometry;
use ising_dgx::observables::{self, binder};
use ising_dgx::util::bench::{quick_mode, write_report};
use ising_dgx::util::json::{obj, Json};
use ising_dgx::util::Table;

fn main() {
    let quick = quick_mode();
    let sizes: Vec<usize> = if quick { vec![32, 64] } else { vec![32, 64, 128] };
    let tc = analytic::critical_temperature();
    let temps: Vec<f64> = (-4i32..=4).map(|k| tc + k as f64 * 0.08).collect();

    let mut header: Vec<String> = vec!["T".into()];
    header.extend(sizes.iter().map(|l| format!("U_L (L={l})")));
    let header_refs: Vec<&str> = header.iter().map(|s| s.as_str()).collect();
    let mut table = Table::new(&header_refs)
        .with_title("Figure 6 — Binder cumulant U_L(T), crossing at Tc");

    // curves[size_index] = Vec<(T, U)>
    let mut curves: Vec<Vec<(f64, f64)>> = vec![Vec::new(); sizes.len()];
    for &t in &temps {
        let mut row = vec![format!("{t:.4}")];
        for (si, &l) in sizes.iter().enumerate() {
            let geom = Geometry::square(l).unwrap();
            let beta = (1.0 / t) as f32;
            let burn = if quick { 1000 } else { 4000 };
            let samples = if quick { 600 } else { 3000 };
            let mut eng = if t < tc {
                MultispinEngine::cold(geom, beta, 11 + l as u32).unwrap()
            } else {
                MultispinEngine::hot(geom, beta, 11 + l as u32).unwrap()
            };
            let meas = observables::measure(&mut eng, burn, samples, 2);
            let u = meas.binder().binder();
            row.push(format!("{u:.4}"));
            curves[si].push((t, u));
        }
        table.row(&row);
    }
    table.print();

    let mut points = Vec::new();
    for (si, &l) in sizes.iter().enumerate() {
        for &(t, u) in &curves[si] {
            points.push(obj(vec![
                ("L", Json::Num(l as f64)),
                ("T", Json::Num(t)),
                ("U", Json::Num(u)),
            ]));
        }
    }

    // Crossing estimates between consecutive sizes.
    println!("Tc = {tc:.6}; U* ≈ {:.4} (universal)", analytic::onsager::BINDER_CRITICAL);
    for si in 0..sizes.len() - 1 {
        match binder::crossing(&curves[si], &curves[si + 1]) {
            Some(t_cross) => {
                println!(
                    "crossing L={} vs L={}: T = {:.4} (Δ from Tc: {:+.4})",
                    sizes[si],
                    sizes[si + 1],
                    t_cross,
                    t_cross - tc
                );
            }
            None => println!(
                "crossing L={} vs L={}: none in window (noise) — widen samples",
                sizes[si],
                sizes[si + 1]
            ),
        }
    }
    println!("shape check — curves decrease through Tc and cross near it (paper Fig. 6).");
    let _ = write_report(
        "fig6_binder",
        &obj(vec![("bench", Json::Str("fig6".into())), ("points", Json::Arr(points))]),
    );
}
