//! Figure 5 — steady-state magnetization vs temperature for several
//! lattice sizes, against the Onsager solution (paper Eq. 7).
//!
//! Paper sizes 512²–4096² scale to 32²–256² here (DESIGN.md §2): the
//! reproduced object is the curve shape — m tracks Eq. 7 below T_c,
//! collapses to 0 above, with finite-size rounding shrinking as L grows.

use ising_dgx::algorithms::MultispinEngine;
use ising_dgx::analytic;
use ising_dgx::lattice::Geometry;
use ising_dgx::observables;
use ising_dgx::util::bench::{quick_mode, write_report};
use ising_dgx::util::json::{obj, Json};
use ising_dgx::util::Table;

fn main() {
    let quick = quick_mode();
    let sizes: Vec<usize> = if quick { vec![32, 64] } else { vec![32, 64, 128, 256] };
    let temps: Vec<f64> = {
        let tc = analytic::critical_temperature();
        let mut t = vec![1.6, 1.8, 2.0, 2.1];
        for k in -2i32..=2 {
            t.push(tc + k as f64 * 0.06);
        }
        t.extend([2.5, 2.7, 3.0]);
        t.sort_by(|a, b| a.partial_cmp(b).unwrap());
        t
    };

    let mut header: Vec<String> = vec!["T".into(), "Onsager".into()];
    header.extend(sizes.iter().map(|l| format!("L={l}")));
    let header_refs: Vec<&str> = header.iter().map(|s| s.as_str()).collect();
    let mut table = Table::new(&header_refs)
        .with_title("Figure 5 — <|m|>(T) vs Onsager Eq. 7 (multi-spin engine)");

    let mut series = Vec::new();
    for &t in &temps {
        let mut row = vec![format!("{t:.4}"), format!("{:.4}", analytic::magnetization(t))];
        let mut entry = vec![
            ("T", Json::Num(t)),
            ("onsager", Json::Num(analytic::magnetization(t))),
        ];
        for &l in &sizes {
            let geom = Geometry::square(l).unwrap();
            let beta = (1.0 / t) as f32;
            // Burn-in scales with L² relaxation away from Tc.
            let burn = if quick { 400 } else { 1500 };
            let samples = if quick { 150 } else { 400 };
            // Cold start below Tc avoids striped metastable states (§5.3).
            let mut eng = if t < analytic::critical_temperature() {
                MultispinEngine::cold(geom, beta, 7 + l as u32).unwrap()
            } else {
                MultispinEngine::hot(geom, beta, 7 + l as u32).unwrap()
            };
            let meas = observables::measure(&mut eng, burn, samples, 2);
            row.push(format!("{:.4}", meas.mean_abs_m()));
            entry.push(("", Json::Null)); // placeholder replaced below
            entry.pop();
            series.push(obj(vec![
                ("T", Json::Num(t)),
                ("L", Json::Num(l as f64)),
                ("abs_m", Json::Num(meas.mean_abs_m())),
                ("err", Json::Num(meas.err_abs_m())),
            ]));
        }
        table.row(&row);
        let _ = entry;
    }
    table.print();
    println!(
        "shape checks — below Tc curves hug Eq. 7 (larger L closer); above Tc they\n\
         collapse toward 0 with |m| ~ L^-7/8 finite-size tails (paper Fig. 5)."
    );
    let _ = write_report(
        "fig5_magnetization",
        &obj(vec![
            ("bench", Json::Str("fig5".into())),
            ("points", Json::Arr(series)),
        ]),
    );
}
