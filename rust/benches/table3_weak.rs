//! Table 3 — weak scaling of the optimized multi-spin code, 1–16 devices,
//! per-device lattice fixed.
//!
//! Two complementary reproductions (DESIGN.md §2):
//!  * measured — NativeCluster on this host (threads share one CPU core,
//!    so wall-clock stays flat; correctness is bit-exact);
//!  * modeled  — the calibrated DGX-2/DGX-2H event model at the paper's
//!    own sizes, which must land on the published endpoints.

use ising_dgx::coordinator::{weak_scaling, NativeCluster, SpinWidth, Topology};
use ising_dgx::lattice::Geometry;
use ising_dgx::util::bench::{quick_mode, write_report};
use ising_dgx::util::json::{obj, Json};
use ising_dgx::util::{units, Table};

/// Paper Table 3: (gpus, dgx2, dgx2h) flips/ns, (123·2048)² spins/GPU.
const PAPER: &[(usize, f64, f64)] = &[
    (1, 417.57, 453.56),
    (2, 828.21, 900.75),
    (4, 1652.79, 1797.18),
    (8, 3284.67, 3571.81),
    (16, 6474.16, 7292.19),
];

fn main() {
    let quick = quick_mode();
    let per_worker = if quick { 128 } else { 256 };
    let sweeps = if quick { 8 } else { 16 };
    let workers: Vec<usize> = vec![1, 2, 4, 8];
    let beta = 0.4406868f32;

    let mut table = Table::new(&["workers", "lattice", "measured flips/ns"])
        .with_title("Table 3a (measured) — native cluster weak scaling, per-worker lattice fixed");
    let mut rows = Vec::new();
    for &n in &workers {
        let geom = Geometry::new(per_worker * n, per_worker).unwrap();
        let mut cluster = NativeCluster::hot(geom, n, beta, 3).unwrap();
        cluster.run(sweeps);
        let rate = cluster.metrics.flips_per_ns();
        table.row(&[
            n.to_string(),
            format!("{}x{}", per_worker * n, per_worker),
            units::fmt_rate(rate),
        ]);
        rows.push(obj(vec![
            ("workers", Json::Num(n as f64)),
            ("flips_per_ns", Json::Num(rate)),
        ]));
    }
    table.print();
    println!("(measured column shares ONE cpu core across workers — expect flat, not linear)");

    let l = 123 * 2048;
    let mut model_rows = Vec::new();
    let mut mt = Table::new(&["gpus", "paper DGX-2", "model DGX-2", "paper DGX-2H", "model DGX-2H"])
        .with_title("Table 3b — paper vs calibrated event model, (123x2048)^2 spins/GPU");
    let m2 = weak_scaling(&Topology::dgx2(), SpinWidth::Nibble, l, l, &[1, 2, 4, 8, 16]);
    let m2h = weak_scaling(&Topology::dgx2h(), SpinWidth::Nibble, l, l, &[1, 2, 4, 8, 16]);
    for (i, &(n, p2, p2h)) in PAPER.iter().enumerate() {
        let (model2, model2h) = (m2[i].1.flips_per_ns, m2h[i].1.flips_per_ns);
        mt.row(&[
            n.to_string(),
            format!("{p2}"),
            units::fmt_sig(model2, 6),
            format!("{p2h}"),
            units::fmt_sig(model2h, 6),
        ]);
        model_rows.push(obj(vec![
            ("gpus", Json::Num(n as f64)),
            ("paper_dgx2", Json::Num(p2)),
            ("model_dgx2", Json::Num(model2)),
            ("paper_dgx2h", Json::Num(p2h)),
            ("model_dgx2h", Json::Num(model2h)),
        ]));
    }
    mt.print();
    println!("shape check — linear weak scaling (paper efficiency @16: 96.9%, model: ~100%).");
    println!("TPU comparison (paper): 64 TPU units = 512 cores reach 5853 flips/ns; one DGX-2 exceeds it.");

    let _ = write_report(
        "table3_weak",
        &obj(vec![
            ("bench", Json::Str("table3_weak".into())),
            ("measured", Json::Arr(rows)),
            ("model", Json::Arr(model_rows)),
        ]),
    );
}
