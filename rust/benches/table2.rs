//! Table 2 — the optimized multi-spin implementation across lattice sizes
//! (paper: 2048² → (123·2048)², 2 MB → 30.3 GB on one V100-SXM; here
//! scaled to 256²..4096², 32 KB → 8 MB packed, DESIGN.md §2). The paper's
//! V100 / TPU / FPGA reference rates are echoed for ratio comparisons.

use ising_dgx::algorithms::MultispinEngine;
use ising_dgx::lattice::Geometry;
use ising_dgx::util::bench::{quick_mode, sweeper_flips_per_ns, write_report};
use ising_dgx::util::json::{obj, Json};
use ising_dgx::util::{units, Table};

/// Paper Table 2 (flips/ns on V100-SXM): (k, rate) for (k·2048)² lattices.
const PAPER_V100: &[(usize, f64)] = &[
    (1, 385.56),
    (2, 409.92),
    (4, 414.21),
    (8, 417.23),
    (16, 417.53),
    (32, 417.57),
    (64, 417.57),
    (123, 417.57),
];
/// Paper comparison rows.
const PAPER_TPU_1: f64 = 12.91;
const PAPER_TPU_32: f64 = 336.01;
const PAPER_FPGA: f64 = 614.0; // 1024² lattice, Ortega-Zamorano et al.

fn main() {
    let quick = quick_mode();
    let sizes: Vec<usize> =
        if quick { vec![256, 512] } else { vec![256, 512, 1024, 2048, 4096] };
    let beta = 0.4406868f32;

    let mut table = Table::new(&["lattice", "memory (packed)", "flips/ns"])
        .with_title("Table 2 (measured) — native multi-spin, single worker");
    let mut rows = Vec::new();
    let mut last = 0.0;
    for &l in &sizes {
        let geom = Geometry::square(l).unwrap();
        let mut engine = MultispinEngine::hot(geom, beta, 1).unwrap();
        // More sweeps on small lattices for timing stability.
        let sweeps = ((1 << 24) / geom.sites()).clamp(4, 512) as u32;
        let rate = sweeper_flips_per_ns(&mut engine, sweeps);
        table.row(&[
            units::fmt_lattice(l),
            units::fmt_bytes(units::lattice_bytes(l, 4)),
            units::fmt_rate(rate),
        ]);
        rows.push(obj(vec![
            ("lattice", Json::Num(l as f64)),
            ("flips_per_ns", Json::Num(rate)),
        ]));
        last = rate;
    }
    table.print();

    let mut paper = Table::new(&["lattice", "flips/ns"])
        .with_title("Table 2 (paper) — V100-SXM optimized multi-spin");
    for &(k, r) in PAPER_V100 {
        paper.row(&[format!("({k}x2048)^2"), format!("{r}")]);
    }
    paper.row(&["1 TPUv3 core [7]".into(), format!("{PAPER_TPU_1}")]);
    paper.row(&["32 TPUv3 cores [7]".into(), format!("{PAPER_TPU_32}")]);
    paper.row(&["FPGA 1024^2 [8]".into(), format!("{PAPER_FPGA}")]);
    paper.print();

    println!(
        "shape checks — throughput saturates with lattice size (paper: 385→417.57);\n\
         ratio vs paper V100 at saturation: {:.1}x slower (1 CPU core vs 5120-core GPU).",
        417.57 / last.max(1e-9)
    );
    let _ = write_report(
        "table2",
        &obj(vec![
            ("bench", Json::Str("table2".into())),
            ("rows", Json::Arr(rows)),
        ]),
    );
}
