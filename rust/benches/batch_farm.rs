//! batch_farm — farm throughput of the bit-sliced 64-replica batch
//! engine vs the per-replica multi-spin farm, at 16/32/64-replica
//! single-β grids (the Block et al. arXiv:1007.3726 replica-batching
//! axis applied to our farm workload).
//!
//! Both farms run with **one worker**, so the comparison isolates the
//! batching lever itself (per-worker throughput) rather than thread
//! scaling — multi-worker scaling is table4's subject. The headline
//! number is aggregate flips/ns against wall clock: the batch farm
//! advances all replicas of a group per instruction, so its rate should
//! exceed the per-replica multispin farm by well over the 4× the CI
//! perf gate's baseline floor encodes (one u64 update drives 64
//! replicas vs 16 same-replica nibbles).

use ising_dgx::coordinator::farm::{run_farm, FarmConfig, FarmEngine};
use ising_dgx::lattice::Geometry;
use ising_dgx::obs::Registry;
use ising_dgx::server::wire::MetricsSnapshot;
use ising_dgx::util::bench::{quick_mode, write_report};
use ising_dgx::util::json::{obj, Json};
use ising_dgx::util::{units, Table};

/// One farm measurement: aggregate wall-clock flips/ns. Each run's wall
/// duration also lands in the shared slice histogram so the perf gate
/// can track tail latency, not just the headline rate.
fn farm_rate(
    metrics: &Registry,
    engine: FarmEngine,
    size: usize,
    replicas: usize,
    samples: usize,
    thin: u64,
) -> f64 {
    let cfg = FarmConfig {
        geom: Geometry::square(size).unwrap(),
        betas: vec![ising_dgx::coordinator::farm::BETA_C],
        seeds: (0..replicas as u32).map(|r| 1 + r).collect(),
        shards: 1,
        workers: 1,
        burn_in: 0,
        samples,
        thin,
        threaded_shards: false,
        threads: 1,
        engine,
    };
    let result = run_farm(&cfg).expect("bench farm must run");
    metrics.observe(
        "ising_slice_duration_seconds",
        "Wall duration of farm passes (scheduler slices and full runs).",
        &[("engine", engine.name())],
        result.wall.as_secs_f64(),
    );
    result.flips_per_ns_wall()
}

fn main() {
    let quick = quick_mode();
    // Quick mode keeps CI fast; full mode is the real measurement.
    let (size, samples, thin) = if quick { (128, 8, 8) } else { (256, 16, 16) };
    let replica_grids: &[usize] = &[16, 32, 64];

    let mut table = Table::new(&[
        "replicas", "multispin farm", "batch farm", "speedup",
    ])
    .with_title(format!(
        "batch_farm — single-β {size}² grids, 1 worker, flips/ns (wall)"
    )
    .as_str());
    let metrics = Registry::new();
    let mut rows = Vec::new();
    for &replicas in replica_grids {
        let multispin = farm_rate(&metrics, FarmEngine::Multispin, size, replicas, samples, thin);
        let batch = farm_rate(&metrics, FarmEngine::Batch, size, replicas, samples, thin);
        let speedup = batch / multispin;
        table.row(&[
            replicas.to_string(),
            units::fmt_rate(multispin),
            units::fmt_rate(batch),
            format!("{speedup:.2}x"),
        ]);
        rows.push(obj(vec![
            ("replicas", Json::Num(replicas as f64)),
            ("multispin_flips_ns", Json::Num(multispin)),
            ("batch_flips_ns", Json::Num(batch)),
            ("speedup", Json::Num(speedup)),
        ]));
    }
    table.print();
    println!(
        "shape checks — batch ≥ 4x multispin at 64 replicas (one u64 update drives 64\n\
         replicas vs 16 same-replica nibbles); speedup grows with replica count as\n\
         lane occupancy fills."
    );

    let _ = write_report(
        "batch_farm",
        &obj(vec![
            ("bench", Json::Str("batch_farm".into())),
            ("size", Json::Num(size as f64)),
            ("samples", Json::Num(samples as f64)),
            ("thin", Json::Num(thin as f64)),
            ("workers", Json::Num(1.0)),
            ("rows", Json::Arr(rows)),
            // Exposition-shaped duration samples: perf_gate.py forwards
            // the histogram series into the merged BENCH_ci.json so CI
            // tracks slice tail latency alongside the rate floors.
            ("metrics", MetricsSnapshot::from_registry(&metrics).to_json()),
        ]),
    );
}
