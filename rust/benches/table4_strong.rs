//! Table 4 — strong scaling of the optimized multi-spin code on a fixed
//! lattice (paper: (123·2048)² over 1–16 GPUs; measured here on a scaled
//! lattice + modeled at paper size, DESIGN.md §2).

use ising_dgx::coordinator::{strong_scaling, NativeCluster, SpinWidth, Topology};
use ising_dgx::lattice::Geometry;
use ising_dgx::util::bench::{quick_mode, write_report};
use ising_dgx::util::json::{obj, Json};
use ising_dgx::util::{units, Table};

/// Paper Table 4: (gpus, dgx2, dgx2h) flips/ns, fixed (123·2048)².
const PAPER: &[(usize, f64, f64)] = &[
    (1, 417.57, 453.56),
    (2, 830.29, 925.99),
    (4, 1629.32, 1848.44),
    (8, 3252.68, 3682.90),
    (16, 6474.16, 7292.19),
];

fn main() {
    let quick = quick_mode();
    let size = if quick { 256 } else { 512 };
    let sweeps = if quick { 8 } else { 16 };
    let beta = 0.4406868f32;
    let geom = Geometry::square(size).unwrap();

    let mut table = Table::new(&["workers", "measured flips/ns", "state == 1-worker?"])
        .with_title(format!("Table 4a (measured) — native cluster strong scaling, {size}^2").as_str());
    let mut rows = Vec::new();
    let mut reference = None;
    for &n in &[1usize, 2, 4, 8] {
        let mut cluster = NativeCluster::hot(geom, n, beta, 4).unwrap();
        cluster.run(sweeps);
        let rate = cluster.metrics.flips_per_ns();
        let same = match &reference {
            None => {
                reference = Some(cluster.lattice.clone());
                true
            }
            Some(want) => &cluster.lattice == want,
        };
        assert!(same, "partition invariance violated at n = {n}");
        table.row(&[n.to_string(), units::fmt_rate(rate), "yes".into()]);
        rows.push(obj(vec![
            ("workers", Json::Num(n as f64)),
            ("flips_per_ns", Json::Num(rate)),
        ]));
    }
    table.print();

    let l = 123 * 2048;
    let mut mt = Table::new(&["gpus", "paper DGX-2", "model DGX-2", "paper DGX-2H", "model DGX-2H"])
        .with_title("Table 4b — paper vs event model, fixed (123x2048)^2");
    let m2 = strong_scaling(&Topology::dgx2(), SpinWidth::Nibble, l, l, &[1, 2, 4, 8, 16]);
    let m2h = strong_scaling(&Topology::dgx2h(), SpinWidth::Nibble, l, l, &[1, 2, 4, 8, 16]);
    let mut model_rows = Vec::new();
    for (i, &(n, p2, p2h)) in PAPER.iter().enumerate() {
        mt.row(&[
            n.to_string(),
            format!("{p2}"),
            units::fmt_sig(m2[i].1.flips_per_ns, 6),
            format!("{p2h}"),
            units::fmt_sig(m2h[i].1.flips_per_ns, 6),
        ]);
        model_rows.push(obj(vec![
            ("gpus", Json::Num(n as f64)),
            ("paper_dgx2", Json::Num(p2)),
            ("model_dgx2", Json::Num(m2[i].1.flips_per_ns)),
        ]));
    }
    mt.print();
    println!("shape check — linear strong scaling: halo transfers negligible vs bulk (paper §5.2).");

    let _ = write_report(
        "table4_strong",
        &obj(vec![
            ("bench", Json::Str("table4_strong".into())),
            ("measured", Json::Arr(rows)),
            ("model", Json::Arr(model_rows)),
        ]),
    );
}
