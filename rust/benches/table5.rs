//! Table 5 — weak and strong scaling of the basic and tensor-core
//! implementations under the multi-device coordinator (paper: unified
//! memory / MPI+IPC on a DGX-2; here: PJRT slab clusters with halo
//! exchange, measured, plus byte-width event-model projections).
//!
//! The measured block needs the `pjrt` feature and AOT artifacts; the
//! paper echo and the event-model projection always run.

use ising_dgx::coordinator::{model_sweep, SpinWidth, Topology};
use ising_dgx::util::bench::write_report;
use ising_dgx::util::json::{obj, Json};
use ising_dgx::util::{units, Table};

/// Paper Table 5 strong-scaling block ((640·128)² fixed): (gpus, py, tc).
const PAPER_STRONG: &[(usize, f64, f64)] = &[
    (1, 43.481, 38.752),
    (2, 83.146, 78.104),
    (4, 165.793, 156.676),
    (8, 330.258, 313.077),
    (16, 650.543, 602.083),
];

fn print_paper() {
    let mut t = Table::new(&["gpus", "Basic(Py)", "TensorCore"])
        .with_title("Table 5 (paper, strong block)");
    for &(n, py, tc) in PAPER_STRONG {
        t.row(&[n.to_string(), format!("{py}"), format!("{tc}")]);
    }
    t.print();
}

/// Model projection at the paper's lattice, byte-wide spins; returns the
/// machine-readable rows for the report.
fn print_model() -> Vec<Json> {
    let l = 640 * 128;
    let topo = Topology { flips_per_ns: 43.481, ..Topology::dgx2() };
    let mut mt = Table::new(&["gpus", "paper Basic(Py)", "model", "paper TensorCore"])
        .with_title("Table 5b — paper strong scaling vs byte-spin event model, (640x128)^2");
    let mut model_rows = Vec::new();
    for &(n, py, tc) in PAPER_STRONG {
        let m = model_sweep(&topo, SpinWidth::Byte, l, l, n);
        mt.row(&[
            n.to_string(),
            format!("{py}"),
            units::fmt_sig(m.flips_per_ns, 6),
            format!("{tc}"),
        ]);
        model_rows.push(obj(vec![
            ("gpus", Json::Num(n as f64)),
            ("paper_python", Json::Num(py)),
            ("model", Json::Num(m.flips_per_ns)),
            ("paper_tensorcore", Json::Num(tc)),
        ]));
    }
    mt.print();
    println!("shape check — both implementations scale ~linearly; tensor-core slightly below basic.");
    model_rows
}

#[cfg(feature = "pjrt")]
fn measured_rows(sweeps: u32, beta: f32) -> Vec<Json> {
    use ising_dgx::coordinator::SlabCluster;
    use ising_dgx::lattice::Geometry;
    use ising_dgx::runtime::{Engine, Variant};
    use std::path::Path;
    use std::rc::Rc;

    let size = 128usize; // slab artifacts exist for 128² and 256²
    let Ok(engine) = Engine::new(Path::new("artifacts")) else {
        eprintln!("artifacts missing — run `make artifacts`; measured block skipped");
        return Vec::new();
    };
    let engine = Rc::new(engine);

    let mut table = Table::new(&["workers", "variant", "measured flips/ns", "bit-exact"])
        .with_title(
            format!("Table 5a (measured) — PJRT slab clusters, {size}^2 strong scaling")
                .as_str(),
        );
    let mut rows = Vec::new();
    for variant in [Variant::Basic, Variant::Tensorcore] {
        let geom = Geometry::square(size).unwrap();
        let mut reference = None;
        for &n in &[1usize, 2, 4] {
            // n=1 uses the plain engine path through a 1-slab cluster when
            // slab artifacts exist for the full height; fall back silently.
            let Ok(mut cluster) =
                SlabCluster::hot(engine.clone(), variant, geom, n, beta, 9)
            else {
                continue;
            };
            cluster.run(sweeps).unwrap();
            let rate = cluster.metrics.flips_per_ns();
            let state = cluster.gather();
            let same = match &reference {
                None => {
                    reference = Some(state);
                    true
                }
                Some(want) => &state == want,
            };
            assert!(same, "slab cluster diverged at n = {n} ({variant:?})");
            table.row(&[
                n.to_string(),
                variant.as_str().into(),
                units::fmt_rate(rate),
                "yes".into(),
            ]);
            rows.push(obj(vec![
                ("workers", Json::Num(n as f64)),
                ("variant", Json::Str(variant.as_str().into())),
                ("flips_per_ns", Json::Num(rate)),
            ]));
        }
    }
    table.print();
    println!("(sequential dispatch on one core: expect flat measured rates; bit-exactness is the point)");
    rows
}

#[cfg(not(feature = "pjrt"))]
fn measured_rows(_sweeps: u32, _beta: f32) -> Vec<Json> {
    eprintln!("table5: built without the `pjrt` feature — measured block skipped");
    Vec::new()
}

fn main() {
    let quick = ising_dgx::util::bench::quick_mode();
    let sweeps = if quick { 4 } else { 8 };
    let beta = 0.4406868f32;

    let rows = measured_rows(sweeps, beta);
    print_paper();
    let model_rows = print_model();

    let _ = write_report(
        "table5",
        &obj(vec![
            ("bench", Json::Str("table5".into())),
            ("measured", Json::Arr(rows)),
            ("model", Json::Arr(model_rows)),
        ]),
    );
}
