//! END-TO-END DRIVER — the full system on a real workload.
//!
//!     make artifacts && cargo run --release --features pjrt --example full_study
//!
//! Exercises every layer in one run and records the numbers EXPERIMENTS.md
//! reports:
//!   L1/L2  — the three Pallas/JAX kernel variants, AOT-compiled to HLO
//!            and executed through PJRT from Rust (`pjrt` feature builds);
//!   L3     — native engines, the multi-device coordinators (halo
//!            exchange, bit-exact vs single device), metrics;
//!   physics — a temperature sweep across the phase transition on a 128²
//!            lattice, validated against the exact Onsager solution
//!            (magnetization + energy) and the Binder cumulant;
//!   performance — flips/ns for every engine (the paper's headline unit).
//!
//! Without the `pjrt` feature the PJRT stages are skipped with a note and
//! the native stages still gate. Exit code is non-zero if any validation
//! gate fails, so this doubles as the repo's end-to-end acceptance test.

use ising_dgx::algorithms::{MultispinEngine, ScalarEngine, Sweeper};
use ising_dgx::analytic;
use ising_dgx::coordinator::NativeCluster;
use ising_dgx::lattice::Geometry;
use ising_dgx::observables;
use ising_dgx::util::bench::{sweeper_flips_per_ns, write_report};
use ising_dgx::util::json::{obj, Json};
use ising_dgx::util::{units, Table};

#[cfg(feature = "pjrt")]
use ising_dgx::coordinator::SlabCluster;
#[cfg(feature = "pjrt")]
use ising_dgx::runtime::{Engine, PjrtEngine, Variant};
#[cfg(feature = "pjrt")]
use std::path::Path;
#[cfg(feature = "pjrt")]
use std::rc::Rc;

fn main() -> ising_dgx::Result<()> {
    let l = 128usize;
    let geom = Geometry::square(l)?;
    let mut failures = Vec::new();
    let mut report_rows = Vec::new();

    // ---- Stage 1: engine inventory + throughput on the real workload.
    println!("== stage 1: engines & throughput ({l}^2, beta = betac) ==");
    let beta_c = analytic::critical_beta() as f32;
    let mut perf = Table::new(&["engine", "flips/ns"]);
    let mut scalar = ScalarEngine::hot(geom, beta_c, 1);
    let scalar_rate = sweeper_flips_per_ns(&mut scalar, 32);
    perf.row(&["native scalar".into(), units::fmt_sig(scalar_rate, 4)]);
    let mut ms = MultispinEngine::hot(geom, beta_c, 1)?;
    let ms_rate = sweeper_flips_per_ns(&mut ms, 32);
    perf.row(&["native multi-spin".into(), units::fmt_sig(ms_rate, 4)]);

    #[cfg_attr(not(feature = "pjrt"), allow(unused_mut))]
    let mut pjrt_rates: Vec<(&'static str, f64)> = Vec::new();
    #[cfg(feature = "pjrt")]
    let engine = Rc::new(Engine::new(Path::new("artifacts"))?);
    #[cfg(feature = "pjrt")]
    {
        for variant in [Variant::Basic, Variant::Multispin, Variant::Tensorcore] {
            let mut e = PjrtEngine::hot(engine.clone(), variant, geom, beta_c, 1)?;
            let rate = sweeper_flips_per_ns(&mut e, 16);
            perf.row(&[e.variant_name().into(), units::fmt_sig(rate, 4)]);
            pjrt_rates.push((e.variant_name(), rate));
        }
    }
    #[cfg(not(feature = "pjrt"))]
    println!("  (pjrt feature disabled — PJRT engine rows skipped)");
    perf.print();
    if ms_rate <= scalar_rate {
        failures.push(format!(
            "multi-spin ({ms_rate:.3}) should outperform scalar ({scalar_rate:.3})"
        ));
    }

    // ---- Stage 2: cross-stack agreement (PJRT vs native, slab vs single).
    println!("\n== stage 2: cross-stack agreement ==");
    let mut native = ScalarEngine::hot(geom, 0.42, 77);
    native.sweep_n(8);
    #[cfg(feature = "pjrt")]
    {
        let mut pjrt = PjrtEngine::hot(engine.clone(), Variant::Basic, geom, 0.42, 77)?;
        pjrt.sweep_n(8);
        let agree = pjrt.spins() == native.spins();
        println!("  PJRT(Pallas basic) == native scalar after 8 sweeps: {agree}");
        if !agree {
            failures.push("PJRT/native trajectory divergence".into());
        }

        let mut cluster =
            SlabCluster::hot(engine.clone(), Variant::Basic, geom, 4, 0.42, 77)?;
        cluster.run(8)?;
        let slab_ok = cluster.gather() == native.lattice;
        println!("  4-device slab cluster == single device: {slab_ok}");
        if !slab_ok {
            failures.push("slab cluster divergence".into());
        }
    }
    #[cfg(not(feature = "pjrt"))]
    println!("  (pjrt feature disabled — PJRT agreement checks skipped)");

    let mut ncluster = NativeCluster::hot(geom, 4, 0.42, 77)?;
    ncluster.run(8);
    let nok = ncluster.lattice.to_checkerboard() == native.lattice;
    println!("  4-worker native cluster == single device: {nok}");
    if !nok {
        failures.push("native cluster divergence".into());
    }

    // ---- Stage 3: physics across the transition vs exact results.
    println!("\n== stage 3: temperature sweep across Tc (multi-spin engine) ==");
    let tc = analytic::critical_temperature();
    let temps = [1.7, 1.9, 2.1, tc - 0.05, tc + 0.05, 2.4, 2.7, 3.0];
    let mut phys = Table::new(&[
        "T", "<|m|>", "Onsager m", "|dm|", "<e>", "exact e", "|de|", "U_L",
    ]);
    for &t in &temps {
        // Cold start below Tc: hot starts coarsen through striped
        // metastable states (paper §5.3) far slower than the sweep budget.
        let mut eng = if t < tc {
            MultispinEngine::cold(geom, (1.0 / t) as f32, 99)?
        } else {
            MultispinEngine::hot(geom, (1.0 / t) as f32, 99)?
        };
        let meas = observables::measure(&mut eng, 2500, 500, 2);
        let m_exact = analytic::magnetization(t);
        let e_exact = analytic::energy_per_site(1.0 / t);
        let dm = (meas.mean_abs_m() - m_exact).abs();
        let de = (meas.mean_e() - e_exact).abs();
        let near_tc = (t - tc).abs() < 0.15;
        // Gates: tight away from Tc, loose inside the critical window.
        if !near_tc && (dm > 0.06 || de > 0.03) {
            failures.push(format!("physics gate failed at T = {t:.3}: dm={dm:.4} de={de:.4}"));
        }
        phys.row(&[
            format!("{t:.4}"),
            format!("{:.4}", meas.mean_abs_m()),
            format!("{m_exact:.4}"),
            format!("{dm:.4}"),
            format!("{:.4}", meas.mean_e()),
            format!("{e_exact:.4}"),
            format!("{de:.4}"),
            format!("{:.4}", meas.binder().binder()),
        ]);
        report_rows.push(obj(vec![
            ("T", Json::Num(t)),
            ("abs_m", Json::Num(meas.mean_abs_m())),
            ("m_exact", Json::Num(m_exact)),
            ("e", Json::Num(meas.mean_e())),
            ("e_exact", Json::Num(e_exact)),
            ("binder", Json::Num(meas.binder().binder())),
        ]));
    }
    phys.print();

    // ---- Verdict + machine-readable record.
    let _ = write_report(
        "full_study",
        &obj(vec![
            ("lattice", Json::Num(l as f64)),
            ("scalar_flips_per_ns", Json::Num(scalar_rate)),
            ("multispin_flips_per_ns", Json::Num(ms_rate)),
            (
                "pjrt_flips_per_ns",
                Json::Arr(
                    pjrt_rates
                        .iter()
                        .map(|(v, r)| {
                            obj(vec![
                                ("variant", Json::Str((*v).into())),
                                ("rate", Json::Num(*r)),
                            ])
                        })
                        .collect(),
                ),
            ),
            ("physics", Json::Arr(report_rows)),
            ("failures", Json::Arr(failures.iter().map(|f| Json::Str(f.clone())).collect())),
        ]),
    );

    if failures.is_empty() {
        println!("\nFULL STUDY: all gates passed ✔ (report: target/bench-reports/full_study.json)");
        Ok(())
    } else {
        for f in &failures {
            eprintln!("FAIL: {f}");
        }
        std::process::exit(1);
    }
}
