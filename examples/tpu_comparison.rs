//! The paper's headline argument in miniature: compare the three
//! single-device implementations (basic stencil, tensor-core matmul,
//! optimized multi-spin) on one lattice and relate the ratios to the
//! paper's V100/TPU numbers.
//!
//!     cargo run --release --example tpu_comparison

use ising_dgx::algorithms::{MultispinEngine, ScalarEngine, Sweeper};
use ising_dgx::lattice::Geometry;
use ising_dgx::util::bench::sweeper_flips_per_ns;
use ising_dgx::util::{units, Table};
#[cfg(feature = "pjrt")]
use ising_dgx::runtime::{Engine, PjrtEngine, Variant};
#[cfg(feature = "pjrt")]
use std::path::Path;
#[cfg(feature = "pjrt")]
use std::rc::Rc;

fn main() -> ising_dgx::Result<()> {
    let l = 256usize;
    let geom = Geometry::square(l)?;
    let beta = 0.4406868f32;
    let sweeps = 16;

    let mut table = Table::new(&["implementation", "flips/ns", "vs scalar"])
        .with_title(&format!("Single-device comparison, {l}^2 lattice"));

    let mut scalar = ScalarEngine::hot(geom, beta, 1);
    let base = sweeper_flips_per_ns(&mut scalar, sweeps);
    table.row(&["native scalar (≙ Basic CUDA C)".into(), units::fmt_rate(base), "1.00x".into()]);

    let mut ms = MultispinEngine::hot(geom, beta, 1)?;
    let r = sweeper_flips_per_ns(&mut ms, sweeps);
    table.row(&[
        "native multi-spin (≙ optimized)".into(),
        units::fmt_rate(r),
        format!("{:.2}x", r / base),
    ]);

    #[cfg(feature = "pjrt")]
    if let Ok(engine) = Engine::new(Path::new("artifacts")) {
        let engine = Rc::new(engine);
        for (variant, label) in [
            (Variant::Basic, "pjrt basic (≙ Basic Python)"),
            (Variant::Tensorcore, "pjrt tensor-core"),
            (Variant::Multispin, "pjrt multi-spin"),
        ] {
            if let Ok(mut e) = PjrtEngine::hot(engine.clone(), variant, geom, beta, 1) {
                let r = sweeper_flips_per_ns(&mut e, sweeps);
                table.row(&[label.into(), units::fmt_rate(r), format!("{:.2}x", r / base)]);
            }
        }
    } else {
        eprintln!("(artifacts missing — run `make artifacts` for the PJRT rows)");
    }
    #[cfg(not(feature = "pjrt"))]
    eprintln!("(built without the `pjrt` feature — PJRT rows skipped)");
    table.print();

    println!("paper (V100 vs TPUv3 core): basic-CUDA 66.95 vs 12.88 flips/ns (5.2x),");
    println!("optimized multi-spin 417.57 vs 12.91 (32x); one V100 ≈ 32 TPUv3 cores.");
    Ok(())
}
