//! Multi-device scaling study (paper §4/§5.2): real slab execution with
//! halo exchange — bit-exact against single-device — plus the calibrated
//! DGX-2 event-model projection to 16 GPUs at the paper's lattice sizes.
//!
//!     cargo run --release --example scaling_study

use ising_dgx::coordinator::{
    strong_scaling, weak_scaling, NativeCluster, SpinWidth, Topology,
};
use ising_dgx::lattice::Geometry;
use ising_dgx::util::{units, Table};
#[cfg(feature = "pjrt")]
use ising_dgx::algorithms::{metropolis, AcceptanceTable};
#[cfg(feature = "pjrt")]
use ising_dgx::coordinator::SlabCluster;
#[cfg(feature = "pjrt")]
use ising_dgx::lattice::init;
#[cfg(feature = "pjrt")]
use ising_dgx::runtime::{Engine, Variant};
#[cfg(feature = "pjrt")]
use std::path::Path;
#[cfg(feature = "pjrt")]
use std::rc::Rc;

fn main() -> ising_dgx::Result<()> {
    let beta = 0.4406868f32;

    // --- Native multi-spin cluster: real execution, partition-invariant.
    println!("== native multi-spin cluster (256^2, strong scaling) ==");
    let geom = Geometry::square(256)?;
    let mut reference = None;
    for n in [1usize, 2, 4, 8] {
        let mut cluster = NativeCluster::hot(geom, n, beta, 7)?;
        cluster.run(16);
        match &reference {
            None => reference = Some(cluster.lattice.clone()),
            Some(want) => assert_eq!(&cluster.lattice, want, "diverged at n={n}"),
        }
        println!(
            "  {n:2} workers: {} flips/ns (state bit-identical to 1 worker)",
            units::fmt_rate(cluster.metrics.flips_per_ns())
        );
    }

    // --- PJRT slab cluster: the Pallas kernels under the coordinator.
    #[cfg(feature = "pjrt")]
    if let Ok(engine) = Engine::new(Path::new("artifacts")) {
        let engine = Rc::new(engine);
        println!("\n== PJRT slab cluster (128^2, basic kernel) ==");
        let geom = Geometry::square(128)?;
        let mut native = init::hot(geom, 9);
        let table = AcceptanceTable::new(beta);
        metropolis::run(&mut native, &table, 9, 0, 8);
        for n in [2usize, 4] {
            let mut cluster = SlabCluster::hot(engine.clone(), Variant::Basic, geom, n, beta, 9)?;
            cluster.run(8)?;
            let ok = cluster.gather() == native;
            println!(
                "  {n} devices: {} flips/ns, matches native single-device: {ok}",
                units::fmt_rate(cluster.metrics.flips_per_ns())
            );
            assert!(ok);
        }
    } else {
        println!("\n(artifacts missing — skipping PJRT cluster; run `make artifacts`)");
    }
    #[cfg(not(feature = "pjrt"))]
    println!("\n(built without the `pjrt` feature — skipping the PJRT slab cluster)");

    // --- DGX-2 event model at paper scale.
    println!("\n== DGX-2 event model, paper lattice (123x2048)^2 ==");
    let l = 123 * 2048;
    let mut t = Table::new(&["gpus", "weak flips/ns", "strong flips/ns", "comm %"]);
    let weak = weak_scaling(&Topology::dgx2(), SpinWidth::Nibble, l, l, &[1, 2, 4, 8, 16]);
    let strong = strong_scaling(&Topology::dgx2(), SpinWidth::Nibble, l, l, &[1, 2, 4, 8, 16]);
    for (i, &(n, w)) in weak.iter().enumerate() {
        t.row(&[
            n.to_string(),
            units::fmt_sig(w.flips_per_ns, 6),
            units::fmt_sig(strong[i].1.flips_per_ns, 6),
            format!("{:.3}%", strong[i].1.comm_fraction * 100.0),
        ]);
    }
    t.print();
    println!("paper endpoints: weak 6474.16, strong 6474.16 flips/ns at 16 GPUs.");
    Ok(())
}
