//! Quickstart: the 60-second tour of the public API.
//!
//!     cargo run --release --example quickstart
//!
//! Builds a lattice, runs the optimized multi-spin engine at two
//! temperatures, and checks the magnetization against Onsager's exact
//! solution (paper Eq. 7).

use ising_dgx::algorithms::{MultispinEngine, Sweeper};
use ising_dgx::analytic;
use ising_dgx::lattice::Geometry;
use ising_dgx::observables;

fn main() -> ising_dgx::Result<()> {
    let geom = Geometry::square(64)?;

    // Ordered phase: T = 1.8 < Tc ≈ 2.269.
    let mut engine = MultispinEngine::hot(geom, (1.0f64 / 1.8) as f32, 42)?;
    let meas = observables::measure(&mut engine, 1000, 300, 2);
    let exact = analytic::magnetization(1.8);
    println!(
        "T = 1.8 (ordered):    <|m|> = {:.4} ± {:.4}   Onsager: {exact:.4}",
        meas.mean_abs_m(),
        meas.err_abs_m()
    );

    // Disordered phase: T = 3.0 > Tc.
    engine.set_beta((1.0f64 / 3.0) as f32);
    let meas = observables::measure(&mut engine, 500, 300, 2);
    println!(
        "T = 3.0 (disordered): <|m|> = {:.4} ± {:.4}   Onsager: 0",
        meas.mean_abs_m(),
        meas.err_abs_m()
    );

    println!("Tc = {:.6} (exact)", analytic::critical_temperature());
    Ok(())
}
