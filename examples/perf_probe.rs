//! Perf probe — the measurement harness behind EXPERIMENTS.md §Perf.
//!
//!     cargo run --release --example perf_probe
//!
//! Reports:
//!   1. Philox throughput: 4 scalar `site_group` calls vs one lockstep
//!      `site_group_x4` (the L3 hot-loop optimization).
//!   2. End-to-end engine rates (scalar vs multi-spin) at 512².
//!   3. PJRT dispatch ablation: flips/ns vs `sweeps_per_call` (the L2/L3
//!      boundary optimization — in-program fori_loop amortizing dispatch
//!      and host round-trips).

use ising_dgx::algorithms::{MultispinEngine, ScalarEngine};
use ising_dgx::lattice::Geometry;
use ising_dgx::rng::{site_group, site_group_x4};
use ising_dgx::util::bench::sweeper_flips_per_ns;
use ising_dgx::util::{units, Timer};
use std::hint::black_box;
#[cfg(feature = "pjrt")]
use ising_dgx::runtime::{Engine, PjrtEngine, Variant};
#[cfg(feature = "pjrt")]
use std::path::Path;
#[cfg(feature = "pjrt")]
use std::rc::Rc;

fn main() -> ising_dgx::Result<()> {
    // --- 1. Philox kernel microbench.
    let iters = 2_000_000u32;
    let t = Timer::start();
    let mut acc = 0u32;
    for i in 0..iters {
        for g in 0..4 {
            acc ^= site_group(1, 0, i, 4 * (i & 0xFFFF) + g, 7)[3];
        }
    }
    black_box(acc);
    let scalar4 = t.secs();
    let t = Timer::start();
    let mut acc = 0u32;
    for i in 0..iters {
        let b = site_group_x4(1, 0, i, 4 * (i & 0xFFFF), 7);
        acc ^= b[0][3] ^ b[1][3] ^ b[2][3] ^ b[3][3];
    }
    black_box(acc);
    let x4 = t.secs();
    println!("philox, {iters} word-groups (16 draws each):");
    println!("  4x scalar site_group : {:.3}s ({:.1} M draws/s)", scalar4, iters as f64 * 16.0 / scalar4 / 1e6);
    println!("  lockstep site_group_x4: {:.3}s ({:.1} M draws/s)  → {:.2}x", x4, iters as f64 * 16.0 / x4 / 1e6, scalar4 / x4);

    // --- 2. Engine rates.
    let geom = Geometry::square(512)?;
    let beta = 0.4406868f32;
    let mut scalar = ScalarEngine::hot(geom, beta, 1);
    let s_rate = sweeper_flips_per_ns(&mut scalar, 8);
    let mut ms = MultispinEngine::hot(geom, beta, 1)?;
    let m_rate = sweeper_flips_per_ns(&mut ms, 8);
    println!("\nengines at 512^2: scalar {} flips/ns, multi-spin {} flips/ns ({:.2}x)",
        units::fmt_sig(s_rate, 4), units::fmt_sig(m_rate, 4), m_rate / s_rate);

    // --- 3. PJRT dispatch ablation.
    #[cfg(feature = "pjrt")]
    if let Ok(engine) = Engine::new(Path::new("artifacts")) {
        let engine = Rc::new(engine);
        let geom = Geometry::square(128)?;
        println!("\npjrt-basic 128^2, flips/ns vs sweeps_per_call:");
        for spc in [1u32, 4, 16, 64] {
            let mut e = PjrtEngine::hot(engine.clone(), Variant::Basic, geom, beta, 1)?;
            e.sweeps_per_call = spc;
            let rate = sweeper_flips_per_ns(&mut e, 64);
            println!("  n={spc:3}: {} flips/ns", units::fmt_sig(rate, 4));
        }
    }
    #[cfg(not(feature = "pjrt"))]
    println!("\n(built without the `pjrt` feature — PJRT dispatch ablation skipped)");
    Ok(())
}
