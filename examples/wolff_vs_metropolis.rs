//! Critical slowing down (paper §2): near T_c local Metropolis dynamics
//! decorrelate slowly (τ ~ L^z, z ≈ 2.17) while Wolff cluster updates
//! stay fast — the reason cluster algorithms exist, and the reason
//! highly-optimized Metropolis implementations (the paper's subject)
//! still matter away from T_c.
//!
//!     cargo run --release --example wolff_vs_metropolis

use ising_dgx::algorithms::{ScalarEngine, Sweeper, WolffEngine};
use ising_dgx::analytic;
use ising_dgx::lattice::Geometry;
use ising_dgx::observables::{self, tau_int};
use ising_dgx::util::Table;

fn main() -> ising_dgx::Result<()> {
    let tc = analytic::critical_temperature();
    let mut table = Table::new(&["T", "tau_int Metropolis", "tau_int Wolff", "ratio"])
        .with_title("Integrated autocorrelation time of |m| (L = 24)");

    let geom = Geometry::square(24)?;
    for &t in &[tc * 1.3, tc * 1.1, tc] {
        let beta = (1.0 / t) as f32;

        let mut metro = ScalarEngine::hot(geom, beta, 31);
        let m = observables::measure(&mut metro, 2000, 2000, 1);
        let tau_m = tau_int(&m.m.iter().map(|x| x.abs()).collect::<Vec<_>>());

        let mut wolff = WolffEngine::hot(geom, beta, 32);
        let w = observables::measure(&mut wolff, 4000, 2000, 1);
        let tau_w = tau_int(&w.m.iter().map(|x| x.abs()).collect::<Vec<_>>());

        table.row(&[
            format!("{t:.4}{}", if (t - tc).abs() < 1e-9 { " (Tc)" } else { "" }),
            format!("{tau_m:.2}"),
            format!("{tau_w:.2}"),
            format!("{:.1}x", tau_m / tau_w),
        ]);
    }
    table.print();
    println!("expected: the ratio grows as T → Tc (critical slowing down of local dynamics).");
    Ok(())
}
