//! 2D ±J Edwards–Anderson spin glass — the extension the paper's
//! conclusion proposes. Shows quenched disorder, frustration-limited
//! energy, and the absence of ferromagnetic order.
//!
//!     cargo run --release --example spin_glass

use ising_dgx::algorithms::acceptance::AcceptanceTable;
use ising_dgx::algorithms::spinglass::{self, Couplings};
use ising_dgx::lattice::{init, Geometry};
use ising_dgx::util::Table;

fn main() -> ising_dgx::Result<()> {
    let geom = Geometry::square(32)?;
    let mut table = Table::new(&["p_ferro", "annealed e/site", "|m|", "note"])
        .with_title("±J spin glass, 32^2, annealed beta: 0.5 -> 4.0");

    for &(p, note) in &[
        (1.0, "pure ferromagnet: e -> -2, |m| -> 1"),
        (0.5, "maximal frustration: e ~ -1.4, |m| ~ 0"),
        (0.0, "pure antiferromagnet: e -> -2 (bipartite), |m| ~ 0"),
    ] {
        let couplings = Couplings::random(geom, 42, p);
        let mut lat = init::hot(geom, 7);
        let mut step = 0u32;
        for beta in [0.5f32, 1.0, 2.0, 4.0] {
            let t = AcceptanceTable::new(beta);
            for _ in 0..300 {
                spinglass::sweep(&mut lat, &couplings, &t, 7, step);
                step += 1;
            }
        }
        let e = spinglass::energy_sum(&lat, &couplings) as f64 / geom.sites() as f64;
        table.row(&[
            format!("{p:.1}"),
            format!("{e:.4}"),
            format!("{:.3}", lat.magnetization().abs()),
            note.into(),
        ]);
    }
    table.print();
    println!("frustration gap: the glass cannot reach the ferromagnetic bound e = -2.");
    Ok(())
}
