//! Metastable banded states (paper §5.3): quench a lattice from a hot
//! start to below T_c and watch it lock into stripes whose lifetime far
//! exceeds the ~L² sweeps naive coarsening suggests.
//!
//!     cargo run --release --example metastability

use ising_dgx::algorithms::{MultispinEngine, Sweeper};
use ising_dgx::lattice::Geometry;
use ising_dgx::observables::stripes;
use ising_dgx::util::Table;

fn main() -> ising_dgx::Result<()> {
    let l = 128usize;
    let geom = Geometry::square(l)?;
    let t_quench = 1.7f64; // deep below Tc
    let mut table = Table::new(&["seed", "sweeps", "|m|", "stripe score", "state"])
        .with_title(&format!("Quench {l}^2 from T=inf to T={t_quench} (L^2/4 sweeps)"));

    let mut striped = 0;
    let seeds = 1u32..=8;
    // Stripes form during coarsening and persist far beyond ~L²/4 sweeps.
    let budget = (l * l / 4) as u64;
    for seed in seeds.clone() {
        let mut eng = MultispinEngine::hot(geom, (1.0 / t_quench) as f32, seed)?;
        eng.sweep_n(budget);
        let board = eng.lattice.to_checkerboard();
        let rep = stripes::analyze(&board);
        let banded = stripes::is_striped(&board);
        striped += banded as u32;
        table.row(&[
            seed.to_string(),
            budget.to_string(),
            format!("{:.3}", rep.abs_m),
            format!("{:.3}", rep.stripe_score),
            if banded { "STRIPED (metastable)".into() } else { "uniform".to_string() },
        ]);
    }
    table.print();
    println!(
        "{striped}/{} quenches stuck in banded metastable states after L^2/4 sweeps —\n\
         the paper reports the same phenomenon on L > 1024 lattices (§5.3) and\n\
         defers its analysis to a follow-up paper.",
        seeds.count()
    );
    Ok(())
}
