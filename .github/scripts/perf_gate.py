#!/usr/bin/env python3
"""CI perf-regression gate over the quick-mode bench JSON reports.

Flattens every report's `rows` into `bench/field/row-key` metrics (all of
them higher-is-better rates or ratios), diffs them against the committed
`benches/baseline.json` floors, and gates:

  * current < baseline * (1 - warn_pct/100)  -> warning  (default 10%)
  * current < baseline * (1 - fail_pct/100)  -> failure  (default 25%)

Only metrics present in BOTH the baseline and the current reports are
gated, so adding a bench row never breaks CI retroactively; a baseline
metric that vanished from the reports is itself a warning (a silently
dropped measurement is how regressions hide).

Reports may additionally embed an observability MetricsSnapshot under
`metrics.samples` (Prometheus-exposition-shaped, the same schema
`GET /v2/metrics` serves). Histogram series from it are forwarded
verbatim into the merged `--out` artifact under `histograms` and
summarised as bucket-derived tail quantiles — recorded for trend
tracking, never gated (durations are lower-is-better, the floors above
are higher-is-better).

Usage:
  perf_gate.py BASELINE REPORT [REPORT...] [--out MERGED]
  perf_gate.py BASELINE REPORT [REPORT...] --update-baseline [--margin PCT]

`--out` additionally writes one merged artifact (the BENCH_ci.json CI
uploads). `--update-baseline` rewrites the baseline's metric floors from
the current run, scaled down by `--margin` (default 40%) so shared-runner
jitter on slower machines does not flap the gate — see README "CI".
"""

import argparse
import json
import sys

ROW_KEY_FIELDS = ("replicas", "lattice", "size", "workers")


def flatten(report):
    """One report dict -> {metric_name: value} over its numeric row fields."""
    name = report.get("bench", "unknown")
    metrics = {}
    for row in report.get("rows", []):
        key_field = next((f for f in ROW_KEY_FIELDS if f in row), None)
        key = row.get(key_field) if key_field else "?"
        if isinstance(key, float) and key.is_integer():
            key = int(key)
        for field, value in row.items():
            if field == key_field:
                continue
            if isinstance(value, bool) or not isinstance(value, (int, float)):
                continue
            metrics[f"{name}/{field}/{key}"] = float(value)
    return metrics


def histogram_samples(report):
    """Histogram exposition samples from an embedded MetricsSnapshot."""
    samples = report.get("metrics", {}).get("samples", [])
    return [s for s in samples if s.get("kind") == "histogram"]


def split_le(labels):
    """Split a rendered label string into (other labels, le edge)."""
    rest, le = [], None
    for pair in filter(None, labels.split(",")):
        if pair.startswith('le="'):
            le = pair[4:-1]
        else:
            rest.append(pair)
    return ",".join(rest), le


def tail_lines(bench, samples, quantiles=(0.5, 0.9)):
    """Bucket-derived upper-bound quantile lines per histogram series.

    Cumulative buckets only bound a quantile from above (the true value
    lies somewhere inside the bucket), so the lines read `p90 <= edge`.
    """
    series = {}
    for s in samples:
        if not s.get("name", "").endswith("_bucket"):
            continue
        family = s["name"][: -len("_bucket")]
        rest, le = split_le(s.get("labels", ""))
        if le is None:
            continue
        edge = float("inf") if le == "+Inf" else float(le)
        series.setdefault((family, rest), []).append((edge, float(s["value"])))
    lines = []
    for (family, rest), buckets in sorted(series.items()):
        buckets.sort()
        total = buckets[-1][1]
        if total <= 0:
            continue
        parts = []
        for q in quantiles:
            edge = next(e for e, c in buckets if c >= q * total)
            bound = "+Inf" if edge == float("inf") else f"{edge}s"
            parts.append(f"p{int(q * 100)} <= {bound}")
        label = f"{{{rest}}}" if rest else ""
        lines.append(f"tail {bench} {family}{label}: {', '.join(parts)} (n={int(total)})")
    return lines


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("baseline")
    ap.add_argument("reports", nargs="+")
    ap.add_argument("--out", help="write the merged BENCH_ci.json artifact here")
    ap.add_argument(
        "--update-baseline",
        action="store_true",
        help="rewrite the baseline floors from this run instead of gating",
    )
    ap.add_argument(
        "--margin",
        type=float,
        default=40.0,
        help="safety margin (pct) below the measured values for --update-baseline",
    )
    args = ap.parse_args()

    with open(args.baseline) as fh:
        baseline = json.load(fh)
    warn_pct = float(baseline.get("warn_pct", 10))
    fail_pct = float(baseline.get("fail_pct", 25))

    current = {}
    loaded_reports = {}
    histograms = {}
    for path in args.reports:
        with open(path) as fh:
            report = json.load(fh)
        name = report.get("bench", path)
        loaded_reports[name] = report
        current.update(flatten(report))
        samples = histogram_samples(report)
        if samples:
            histograms[name] = samples
            for line in tail_lines(name, samples):
                print(line)

    if args.out:
        with open(args.out, "w") as fh:
            json.dump(
                {"metrics": current, "histograms": histograms, "reports": loaded_reports},
                fh,
                indent=2,
                sort_keys=True,
            )
            fh.write("\n")
        print(
            f"merged artifact -> {args.out} "
            f"({len(current)} metrics, {len(histograms)} histogram set(s))"
        )

    if args.update_baseline:
        floors = {
            k: round(v * (1.0 - args.margin / 100.0), 6) for k, v in sorted(current.items())
        }
        baseline["metrics"] = floors
        with open(args.baseline, "w") as fh:
            json.dump(baseline, fh, indent=2, sort_keys=True)
            fh.write("\n")
        print(f"baseline rewritten with {len(floors)} floors (margin {args.margin}%)")
        return 0

    failures, warnings = [], []
    for key, base in sorted(baseline.get("metrics", {}).items()):
        if base <= 0:
            continue
        if key not in current:
            warnings.append(f"{key}: baselined at {base} but absent from this run")
            continue
        cur = current[key]
        drop = (base - cur) / base * 100.0
        line = f"{key}: {cur:.4g} vs baseline floor {base:.4g} ({drop:+.1f}% below floor)"
        if cur < base * (1.0 - fail_pct / 100.0):
            failures.append(line)
        elif cur < base * (1.0 - warn_pct / 100.0):
            warnings.append(line)
        else:
            print(f"ok   {key}: {cur:.4g} (floor {base:.4g})")

    for w in warnings:
        print(f"::warning title=perf regression::{w}")
    for f in failures:
        print(f"::error title=perf regression::{f}")
    if failures:
        print(f"perf gate: {len(failures)} metric(s) regressed > {fail_pct}% below baseline")
        return 1
    print(
        f"perf gate: clean ({len(warnings)} warning(s); "
        f"thresholds warn>{warn_pct}% fail>{fail_pct}%)"
    )
    return 0


if __name__ == "__main__":
    sys.exit(main())
